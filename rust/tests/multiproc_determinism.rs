//! End-to-end multi-process determinism.
//!
//! The acceptance theorem for the multi-process trainer: training across
//! **real worker processes** (≥ 2, spawned from the `lnsdnn` binary,
//! over stdio pipes and loopback TCP) produces weights, per-epoch
//! losses, and test metrics **bit-identical** to the in-process sharded
//! trainer and to the serial trainer, on all four backends. Plus the
//! wire-format hard-failure guarantees: version mismatch, corruption,
//! and dead workers are errors, never silent regroupings.

use lnsdnn::coordinator::server::{train_cnn_multiproc, train_multiproc, MultiprocSpec};
use lnsdnn::data::{stripes_dataset, synth_dataset, Dataset, StripeSpec, SynthSpec};
use lnsdnn::fixed::{FixedConfig, FixedSystem};
use lnsdnn::lns::{LnsConfig, LnsSystem};
use lnsdnn::nn::{Cnn, InitScheme, Mlp, SgdConfig};
use lnsdnn::tensor::{Backend, FixedBackend, FloatBackend, LnsBackend};
use lnsdnn::train::wire::{self, FrameKind, WireElem};
use lnsdnn::train::{
    train, train_cnn, CnnTrainConfig, ShardConfig, TrainConfig, TrainResult, Transport,
};
use std::path::PathBuf;

fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_lnsdnn"))
}

fn mp_spec(workers: usize, transport: Transport) -> MultiprocSpec {
    let mut spec = MultiprocSpec::new(workers);
    spec.worker_exe = Some(worker_exe());
    spec.transport = transport;
    spec.worker_threads = 1;
    spec
}

fn tiny_ds() -> Dataset {
    synth_dataset(&SynthSpec {
        name: "tiny".into(),
        classes: 3,
        train_per_class: 14,
        test_per_class: 5,
        strokes: 4,
        jitter_px: 1.5,
        jitter_rot: 0.15,
        noise: 0.04,
        seed: 42,
    })
}

fn tiny_cfg() -> TrainConfig {
    TrainConfig {
        // n = 42 → 34 train after the 1:5 hold-back → batch 5 leaves a
        // 4-sample partial final batch, so the partial-batch paths are
        // exercised too.
        dims: vec![784, 8, 3],
        epochs: 2,
        batch_size: 5,
        sgd: SgdConfig { lr: 0.02, weight_decay: 1e-4 },
        val_ratio: 5,
        init: InitScheme::HeNormal,
        seed: 3,
        shard: ShardConfig::default(),
        precision: lnsdnn::precision::PrecisionMap::uniform(),
    }
}

fn assert_mlp_runs_equal<E: PartialEq + std::fmt::Debug>(
    label: &str,
    a: &TrainResult<Mlp<E>>,
    b: &TrainResult<Mlp<E>>,
) {
    assert_eq!(a.model.layers.len(), b.model.layers.len(), "{label}: layer count");
    for l in 0..a.model.layers.len() {
        assert_eq!(a.model.layers[l].w.data, b.model.layers[l].w.data, "{label}: layer {l} w");
        assert_eq!(a.model.layers[l].b, b.model.layers[l].b, "{label}: layer {l} b");
    }
    assert_eq!(a.test.accuracy, b.test.accuracy, "{label}: test accuracy");
    assert_eq!(a.test.loss, b.test.loss, "{label}: test loss");
    assert_eq!(a.curve.len(), b.curve.len(), "{label}: curve length");
    for (x, y) in a.curve.iter().zip(&b.curve) {
        assert_eq!(x.train_loss, y.train_loss, "{label}: epoch {} train loss", x.epoch);
        assert_eq!(x.val_accuracy, y.val_accuracy, "{label}: epoch {} val acc", x.epoch);
    }
}

/// Serial ≡ in-process shards=2 ≡ two worker processes, for one backend.
fn check_mlp_backend<B, F>(label: &str, mk: F)
where
    B: Backend,
    B::E: WireElem,
    F: Fn() -> B,
{
    let ds = tiny_ds();
    let cfg = tiny_cfg();
    let serial = train(&mk(), &ds, &cfg);
    let mut sharded_cfg = cfg.clone();
    sharded_cfg.shard = ShardConfig::with_shards(2);
    let sharded = train(&mk(), &ds, &sharded_cfg);
    let spec = mp_spec(2, Transport::Stdio);
    let mp = train_multiproc(&mk(), &ds, &cfg, &spec)
        .unwrap_or_else(|e| panic!("{label}: multi-process run failed: {e:#}"));
    assert_mlp_runs_equal(&format!("{label} serial vs multiproc"), &serial, &mp);
    assert_mlp_runs_equal(&format!("{label} sharded vs multiproc"), &sharded, &mp);
}

#[test]
fn mlp_multiproc_bit_identical_float() {
    check_mlp_backend("float32", FloatBackend::default);
}

#[test]
fn mlp_multiproc_bit_identical_fixed16() {
    check_mlp_backend("lin16", || {
        FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01)
    });
}

#[test]
fn mlp_multiproc_bit_identical_lns16_lut() {
    check_mlp_backend("log16-lut", || {
        LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01)
    });
}

#[test]
fn mlp_multiproc_bit_identical_lns16_bitshift() {
    check_mlp_backend("log16-bs", || {
        LnsBackend::new(LnsSystem::new(LnsConfig::w16_bitshift()), 0.01)
    });
}

#[test]
fn mlp_multiproc_bit_identical_lns8_lut() {
    // The narrow end of the runtime width axis (PR 10): the worker
    // processes reconstruct the w8 config from the `log8-lut` tag, and
    // the act-probe handshake must accept it.
    check_mlp_backend("log8-lut", || {
        LnsBackend::new(LnsSystem::new(LnsConfig::w8_lut()), 0.01)
    });
}

#[test]
fn worker_count_and_transport_do_not_change_bits() {
    let ds = tiny_ds();
    let cfg = tiny_cfg();
    let serial = train(&FloatBackend::default(), &ds, &cfg);
    let three = train_multiproc(&FloatBackend::default(), &ds, &cfg, &mp_spec(3, Transport::Stdio))
        .expect("3-worker stdio run failed");
    assert_mlp_runs_equal("serial vs 3 workers", &serial, &three);
    let tcp = train_multiproc(&FloatBackend::default(), &ds, &cfg, &mp_spec(2, Transport::Tcp))
        .expect("2-worker tcp run failed");
    assert_mlp_runs_equal("serial vs tcp", &serial, &tcp);
}

fn cnn_fixture() -> (Dataset, CnnTrainConfig) {
    let ds = stripes_dataset(&StripeSpec {
        train_per_class: 8,
        test_per_class: 3,
        ..StripeSpec::cnn_default(1.0, 17)
    });
    let mut cfg = CnnTrainConfig::lenet(12, 4);
    cfg.arch.c1 = 2;
    cfg.arch.c2 = 3;
    cfg.arch.hidden = 8;
    cfg.epochs = 1;
    cfg.sgd = SgdConfig { lr: 0.02, weight_decay: 0.0 };
    cfg.seed = 19;
    (ds, cfg)
}

fn assert_cnn_runs_equal<E: PartialEq + std::fmt::Debug>(
    label: &str,
    a: &TrainResult<Cnn<E>>,
    b: &TrainResult<Cnn<E>>,
) {
    assert_eq!(a.model.conv1.w.data, b.model.conv1.w.data, "{label}: conv1 w");
    assert_eq!(a.model.conv2.w.data, b.model.conv2.w.data, "{label}: conv2 w");
    assert_eq!(a.model.fc1.w.data, b.model.fc1.w.data, "{label}: fc1 w");
    assert_eq!(a.model.fc2.w.data, b.model.fc2.w.data, "{label}: fc2 w");
    assert_eq!(a.model.conv1.b, b.model.conv1.b, "{label}: conv1 b");
    assert_eq!(a.model.fc2.b, b.model.fc2.b, "{label}: fc2 b");
    assert_eq!(a.test.accuracy, b.test.accuracy, "{label}: test accuracy");
    assert_eq!(a.test.loss, b.test.loss, "{label}: test loss");
}

#[test]
fn cnn_multiproc_bit_identical_float_and_lns() {
    let (ds, cfg) = cnn_fixture();
    let inproc = train_cnn(&FloatBackend::default(), &ds, &cfg);
    let mp = train_cnn_multiproc(&FloatBackend::default(), &ds, &cfg, &mp_spec(2, Transport::Stdio))
        .expect("float CNN multi-process run failed");
    assert_cnn_runs_equal("cnn float", &inproc, &mp);

    let mk = || LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
    let inproc = train_cnn(&mk(), &ds, &cfg);
    let mp = train_cnn_multiproc(&mk(), &ds, &cfg, &mp_spec(2, Transport::Stdio))
        .expect("LNS CNN multi-process run failed");
    assert_cnn_runs_equal("cnn log16-lut", &inproc, &mp);
}

#[test]
fn dead_worker_binary_is_a_hard_error() {
    let ds = tiny_ds();
    let cfg = tiny_cfg();
    let mut spec = mp_spec(2, Transport::Stdio);
    // A "worker" that exits immediately without speaking the protocol.
    spec.worker_exe = Some(PathBuf::from("/bin/false"));
    let err = train_multiproc(&FloatBackend::default(), &ds, &cfg, &spec)
        .expect_err("a dead worker must abort the run");
    let msg = format!("{err:#}");
    assert!(msg.contains("worker"), "{msg}");
}

#[test]
fn worker_process_rejects_version_mismatch() {
    use std::io::Write;
    use std::process::{Command, Stdio};
    // Hand the worker a job frame stamped with a future wire version:
    // it must refuse and exit non-zero, not guess at the layout.
    let mut bad = Vec::new();
    wire::write_frame_with_version(&mut bad, wire::WIRE_VERSION + 1, FrameKind::Job, b"whatever")
        .unwrap();
    let mut child = Command::new(worker_exe())
        .args(["worker", "--transport", "stdio"])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning worker");
    child.stdin.take().unwrap().write_all(&bad).expect("writing bad frame");
    let status = child.wait().expect("waiting for worker");
    assert!(!status.success(), "worker must reject a wire version mismatch");
}

#[test]
fn frame_roundtrip_and_corruption_rejection() {
    // Round-trip.
    let mut buf = Vec::new();
    wire::write_frame(&mut buf, FrameKind::Merged, b"gradient payload").unwrap();
    let frame = wire::read_frame(&mut buf.as_slice()).unwrap();
    assert_eq!(frame.kind, FrameKind::Merged);
    assert_eq!(frame.payload, b"gradient payload");

    // A single flipped payload bit is detected.
    let mut corrupt = buf.clone();
    let last = corrupt.len() - 1;
    corrupt[last] ^= 0x40;
    let err = wire::read_frame(&mut corrupt.as_slice()).unwrap_err();
    assert!(err.to_string().contains("checksum"), "{err}");

    // A version bump is rejected with both versions named.
    let mut vbuf = Vec::new();
    wire::write_frame_with_version(&mut vbuf, wire::WIRE_VERSION + 7, FrameKind::Digest, b"x")
        .unwrap();
    let err = wire::read_frame(&mut vbuf.as_slice()).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("version mismatch"), "{msg}");
    assert!(msg.contains(&format!("v{}", wire::WIRE_VERSION)), "{msg}");
}
