//! End-to-end PJRT benchmarks: artifact compile/execute latency for the
//! forward (serving) and train-step paths, against the native engine on
//! identical work. Quantifies what the AOT boundary costs/buys.
//!
//! Requires `make artifacts` (skips politely otherwise).

use lnsdnn::bench_util::{bench, black_box};
use lnsdnn::lns::{LnsConfig, LnsSystem, LnsValue, ZERO_M};
use lnsdnn::nn::mlp::Dense;
use lnsdnn::nn::{Mlp, SgdConfig};
use lnsdnn::rng::SplitMix64;
use lnsdnn::runtime::{ArtifactExecutable, ArtifactRegistry, Runtime};
use lnsdnn::tensor::{LnsBackend, Tensor};
use std::path::PathBuf;

const DIMS: [usize; 3] = [784, 100, 10];

fn random_planes(rng: &mut SplitMix64, sys: &LnsSystem, n: usize) -> (Vec<i32>, Vec<i32>) {
    let (lo, hi) = (sys.config().m_min() as i64, sys.config().m_max() as i64);
    (0..n)
        .map(|_| {
            if rng.next_f64() < 0.1 {
                (ZERO_M, 1)
            } else {
                let m = (lo + rng.next_below((hi - lo + 1) as u64) as i64) as i32;
                (m, rng.next_below(2) as i32)
            }
        })
        .unzip()
}

fn main() {
    let dir = PathBuf::from("artifacts");
    if !dir.join("manifest.tsv").exists() {
        println!("SKIP: artifacts/ missing (run `make artifacts`)");
        return;
    }
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let mut reg = ArtifactRegistry::open(&dir).unwrap();
    println!("platform {} ({} devices)\n", rt.platform(), rt.device_count());

    let sys = LnsSystem::new(LnsConfig::w16_lut());
    let backend = LnsBackend::new(sys.clone(), 0.01);
    let mut rng = SplitMix64::new(42);

    // Parameters + inputs.
    let mut planes = Vec::new();
    for l in 0..2 {
        let (fi, fo) = (DIMS[l], DIMS[l + 1]);
        planes.push(random_planes(&mut rng, &sys, fi * fo));
        planes.push(random_planes(&mut rng, &sys, fo));
    }
    let param_lits = |planes: &[(Vec<i32>, Vec<i32>)]| -> Vec<xla::Literal> {
        let mut v = Vec::new();
        for l in 0..2 {
            let (fi, fo) = (DIMS[l] as i64, DIMS[l + 1] as i64);
            v.push(ArtifactExecutable::lit_i32(&planes[2 * l].0, &[fi, fo]).unwrap());
            v.push(ArtifactExecutable::lit_i32(&planes[2 * l].1, &[fi, fo]).unwrap());
            v.push(ArtifactExecutable::lit_i32(&planes[2 * l + 1].0, &[fo]).unwrap());
            v.push(ArtifactExecutable::lit_i32(&planes[2 * l + 1].1, &[fo]).unwrap());
        }
        v
    };

    // Compile latency (fresh parse+compile per iteration).
    println!("-- artifact compile (HLO text parse + XLA compile) --");
    let meta = reg.meta("lns_fwd_w16_lut_paper").unwrap().clone();
    bench("compile/lns_fwd_paper", None, || {
        black_box(rt.load_hlo_text(&dir.join(&meta.file)).unwrap());
    });

    // Forward execute, batch 64.
    println!("\n-- forward, batch 64 (serving path) --");
    let exe = reg.load(&rt, "lns_fwd_w16_lut_paper").unwrap();
    let x64 = random_planes(&mut rng, &sys, 64 * DIMS[0]);
    let mut inputs = param_lits(&planes);
    inputs.push(ArtifactExecutable::lit_i32(&x64.0, &[64, DIMS[0] as i64]).unwrap());
    inputs.push(ArtifactExecutable::lit_i32(&x64.1, &[64, DIMS[0] as i64]).unwrap());
    bench("pjrt/fwd batch=64", Some(64.0), || {
        black_box(exe.run(&inputs).unwrap());
    });

    let to_vals = |m: &[i32], s: &[i32]| -> Vec<LnsValue> {
        m.iter().zip(s).map(|(&m, &s)| LnsValue::new(m, s == 1)).collect()
    };
    let mlp = Mlp {
        dims: DIMS.to_vec(),
        layers: vec![
            Dense {
                w: Tensor::from_vec(784, 100, to_vals(&planes[0].0, &planes[0].1)),
                b: to_vals(&planes[1].0, &planes[1].1),
            },
            Dense {
                w: Tensor::from_vec(100, 10, to_vals(&planes[2].0, &planes[2].1)),
                b: to_vals(&planes[3].0, &planes[3].1),
            },
        ],
    };
    let xt = Tensor::from_vec(64, DIMS[0], to_vals(&x64.0, &x64.1));
    bench("native/fwd batch=64", Some(64.0), || {
        black_box(mlp.logits(&backend, &xt));
    });

    // Train step, batch 5.
    println!("\n-- train step, batch 5 (paper protocol) --");
    let exe_t = {
        let m = reg.meta("lns_train_w16_lut_paper").unwrap().clone();
        rt.load_hlo_text(&dir.join(&m.file)).unwrap()
    };
    let x5 = random_planes(&mut rng, &sys, 5 * DIMS[0]);
    let labels: Vec<i32> = (0..5).map(|i| (i % 10) as i32).collect();
    let mut tin = param_lits(&planes);
    tin.push(ArtifactExecutable::lit_i32(&x5.0, &[5, DIMS[0] as i64]).unwrap());
    tin.push(ArtifactExecutable::lit_i32(&x5.1, &[5, DIMS[0] as i64]).unwrap());
    tin.push(ArtifactExecutable::lit_i32(&labels, &[5]).unwrap());
    bench("pjrt/train_step batch=5", Some(5.0), || {
        black_box(exe_t.run(&tin).unwrap());
    });

    let x5t = Tensor::from_vec(5, DIMS[0], to_vals(&x5.0, &x5.1));
    let lbl: Vec<usize> = labels.iter().map(|&l| l as usize).collect();
    let sgd = SgdConfig { lr: 0.01, weight_decay: 1e-4 };
    bench("native/train_step batch=5", Some(5.0), || {
        let mut m = mlp.clone();
        let (g, _) = m.backprop(&backend, &x5t, &lbl);
        sgd.apply(&backend, &mut m, &g);
        black_box(m);
    });

    println!("\n(The PJRT path carries the interpret-mode Pallas lowering — its");
    println!("CPU numbers gauge the AOT boundary, not TPU perf; see DESIGN.md §7.)");
}
