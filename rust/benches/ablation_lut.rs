//! Ablation: Δ-LUT shape co-optimization (the paper's §6 future work).
//!
//! Sweeps the MAC table over dynamic range and resolution, training one
//! 16-bit LNS model per shape, and reports accuracy vs table size vs a
//! first-order gate-count proxy → `results/ablation_lut.csv`. The
//! paper's chosen point (d_max = 10, r = 1/2, 20 entries) should sit on
//! the knee: smaller ranges/coarser resolutions lose accuracy, larger
//! tables buy little.

use lnsdnn::coordinator::experiments::lut_sweep;
use lnsdnn::coordinator::report;
use lnsdnn::data::{synth_dataset, SynthSpec};
use std::path::Path;

fn main() {
    let ds = synth_dataset(&SynthSpec::mnist_like(0.02, 7));
    println!(
        "Δ-LUT sweep on {} ({} train / {} test), 6 epochs, hidden 48:",
        ds.name,
        ds.train_len(),
        ds.test_len()
    );
    // (d_max, log2(1/r)): range sweep at r=1/2, resolution sweep at d_max=10.
    let shapes = [
        (2u32, 1u32),
        (4, 1),
        (6, 1),
        (10, 1), // paper's MAC table (20 entries)
        (16, 1),
        (10, 0), // r = 1 (bit-shift-sized)
        (10, 3), // r = 1/8  (80 entries)
        (10, 6), // r = 1/64 (640 entries, the paper's softmax table)
    ];
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let rows = lut_sweep(&ds, &shapes, 6, 48, 7, threads);

    let csv: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.d_max.to_string(),
                format!("{}", 1 << r.log2_inv_r),
                r.table_len.to_string(),
                format!("{:.0}", r.gates),
                format!("{:.4}", r.test_accuracy),
            ]
        })
        .collect();
    report::write_csv(
        Path::new("results/ablation_lut.csv"),
        &["d_max", "inv_r", "table_len", "gates", "test_accuracy"],
        &csv,
    )
    .unwrap();
    println!("→ results/ablation_lut.csv");

    // Shape assertions: the paper's point is on the knee.
    let acc = |d: u32, l: u32| {
        rows.iter().find(|r| r.d_max == d && r.log2_inv_r == l).unwrap().test_accuracy
    };
    let paper = acc(10, 1);
    assert!(
        paper > acc(2, 1) - 0.02,
        "d_max=10 should beat (or match) a truncated d_max=2 range"
    );
    assert!(
        acc(10, 6) - paper < 0.05,
        "32× more entries should buy little beyond the paper's 20"
    );
    println!(
        "knee check: paper(20 entries) {:.3}; d_max=2 {:.3}; 640 entries {:.3}",
        paper,
        acc(2, 1),
        acc(10, 6)
    );
}
