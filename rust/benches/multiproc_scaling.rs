//! Multi-process scaling: training samples/s for the serial trainer,
//! the in-process sharded trainer, and the multi-process trainer
//! (stdio + TCP transports), float and 16-bit LNS-LUT.
//!
//! The trained weights are bit-identical across every row of a backend's
//! table (`tests/multiproc_determinism.rs`), so like `shard_scaling`
//! this bench measures the only thing the axes are allowed to move:
//! wall-clock. The multi-process rows pay for B gradient-sized frames up
//! and one broadcast down per step (see `train::multiproc` docs), so
//! they are expected to trail the in-process rows at the paper's tiny
//! batch sizes — the point of the table is to *see* that serialization
//! tax next to the contract it buys.
//!
//! Timing uses the epoch records' step seconds (training steps only —
//! evaluation and encoding are excluded).

use lnsdnn::coordinator::server::{train_multiproc, MultiprocSpec};
use lnsdnn::data::{synth_dataset, Dataset, SynthSpec};
use lnsdnn::lns::{LnsConfig, LnsSystem};
use lnsdnn::nn::{InitScheme, SgdConfig};
use lnsdnn::tensor::{Backend, FloatBackend, LnsBackend};
use lnsdnn::train::wire::WireElem;
use lnsdnn::train::{train, ShardConfig, TrainConfig, Transport};
use std::path::PathBuf;

const WORKER_COUNTS: [usize; 2] = [2, 4];

fn worker_exe() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_lnsdnn"))
}

fn bench_cfg(classes: usize) -> TrainConfig {
    TrainConfig {
        dims: vec![784, 32, classes],
        epochs: 2,
        batch_size: 16,
        sgd: SgdConfig { lr: 0.02, weight_decay: 0.0 },
        val_ratio: 5,
        init: InitScheme::HeNormal,
        seed: 7,
        shard: ShardConfig::default(),
        precision: lnsdnn::precision::PrecisionMap::uniform(),
    }
}

fn step_seconds(curve: &[lnsdnn::train::EpochRecord]) -> f64 {
    curve.iter().map(|e| e.seconds).sum()
}

fn report_row(label: &str, samples: f64, secs: f64, base: f64) {
    let rate = samples / secs;
    println!("  {label:<26} {secs:>8.2}s {rate:>12.0} samples/s {:>8.2}x", base / secs);
}

fn bench_backend<B, F>(tag: &str, mk: F, ds: &Dataset)
where
    B: Backend,
    B::E: WireElem,
    F: Fn() -> B,
{
    let cfg = bench_cfg(ds.classes);
    let n = ds.train_len();
    let samples = ((n - n / cfg.val_ratio) * cfg.epochs) as f64;
    println!("{tag}:");

    let serial = train(&mk(), ds, &cfg);
    let base = step_seconds(&serial.curve);
    report_row("serial (in-process)", samples, base, base);

    for shards in WORKER_COUNTS {
        let mut c = cfg.clone();
        c.shard = ShardConfig::with_shards(shards);
        let r = train(&mk(), ds, &c);
        assert_eq!(r.test.accuracy, serial.test.accuracy, "shards={shards} must be bit-exact");
        report_row(&format!("in-process shards={shards}"), samples, step_seconds(&r.curve), base);
    }

    for workers in WORKER_COUNTS {
        let mut spec = MultiprocSpec::new(workers);
        spec.worker_exe = Some(worker_exe());
        spec.worker_threads = 1;
        let r = train_multiproc(&mk(), ds, &cfg, &spec).expect("multi-process run failed");
        assert_eq!(r.test.accuracy, serial.test.accuracy, "workers={workers} must be bit-exact");
        assert_eq!(r.test.loss, serial.test.loss, "workers={workers} must be bit-exact");
        report_row(
            &format!("processes={workers} (stdio)"),
            samples,
            step_seconds(&r.curve),
            base,
        );
    }

    let mut spec = MultiprocSpec::new(2);
    spec.worker_exe = Some(worker_exe());
    spec.transport = Transport::Tcp;
    spec.worker_threads = 1;
    let r = train_multiproc(&mk(), ds, &cfg, &spec).expect("multi-process tcp run failed");
    assert_eq!(r.test.accuracy, serial.test.accuracy, "tcp transport must be bit-exact");
    assert_eq!(r.test.loss, serial.test.loss, "tcp transport must be bit-exact");
    report_row("processes=2 (tcp)", samples, step_seconds(&r.curve), base);
    println!();
}

fn main() {
    let ds = synth_dataset(&SynthSpec::mnist_like(0.01, 7));
    println!(
        "multiproc scaling: {} — {} train / {} test, {} epochs, batch {}\n",
        ds.name,
        ds.train_len(),
        ds.test_len(),
        bench_cfg(ds.classes).epochs,
        bench_cfg(ds.classes).batch_size
    );
    bench_backend("float32", FloatBackend::default, &ds);
    bench_backend(
        "log16-lut",
        || LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01),
        &ds,
    );
    println!("every row above trained bit-identical weights (asserted on test metrics).");
}
