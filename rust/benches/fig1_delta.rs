//! Fig. 1 regenerator + Δ-evaluation throughput.
//!
//! Emits `results/fig1_delta.csv` (the exact series of the paper's
//! figure: Δ± exact vs 20-entry LUT vs bit-shift over d ∈ [0, 11]) and
//! benchmarks the three Δ evaluations — the op the paper's hardware
//! argument turns on.

use lnsdnn::bench_util::{bench, black_box};
use lnsdnn::coordinator::{experiments, report};
use lnsdnn::lns::{DeltaApprox, DeltaMode, LnsConfig, LutSpec};
use lnsdnn::rng::SplitMix64;
use std::path::Path;

fn main() {
    // Regenerate the figure data.
    let rows = experiments::fig1_rows(11.0, 441);
    report::write_csv(
        Path::new("results/fig1_delta.csv"),
        &["d", "exact_plus", "lut_plus", "bs_plus", "exact_minus", "lut_minus", "bs_minus"],
        &report::fig1_csv_rows(&rows),
    )
    .expect("write fig1 csv");
    println!("Fig. 1 series → results/fig1_delta.csv ({} samples)", rows.len());

    // Shape checks the figure must satisfy (the paper's visual claims).
    let max_lut_err = rows
        .iter()
        .map(|r| (r.lut_plus - r.exact_plus).abs())
        .fold(0.0f64, f64::max);
    let max_bs_err = rows
        .iter()
        .filter(|r| r.d < 10.0)
        .map(|r| (r.bs_plus - r.exact_plus).abs())
        .fold(0.0f64, f64::max);
    println!("  max |LUT − exact| over range: {max_lut_err:.4} (bin width 1/2)");
    println!("  max |BS − exact| over d<10 : {max_bs_err:.4} (r = 1 equivalent)");
    // Floor-indexed bins: worst case is just below a bin edge, where the
    // d=0 entry (Δ+=1) serves d→0.5⁻ (exact 0.77) ⇒ ~0.22.
    assert!(max_lut_err < 0.25, "LUT should stay close to exact");
    assert!(max_bs_err > max_lut_err, "bit-shift is the coarser approximation");

    // Throughput of the Δ+ evaluation itself.
    println!("\n-- Δ+ evaluation throughput (65k random d per iter) --");
    let cfg = LnsConfig::w16_lut();
    let mut rng = SplitMix64::new(1);
    let ds: Vec<i64> = (0..65_536).map(|_| (rng.next_below(12 << 10)) as i64).collect();
    for (label, mode) in [
        ("lut20", DeltaMode::Lut(LutSpec::MAC20)),
        ("lut640", DeltaMode::Lut(LutSpec::SOFTMAX640)),
        ("bitshift", DeltaMode::BitShift),
        ("exact", DeltaMode::Exact),
    ] {
        let ap = DeltaApprox::new(&cfg, mode);
        bench(&format!("delta_plus/{label}"), Some(ds.len() as f64), || {
            let mut acc = 0i64;
            for &d in &ds {
                acc = acc.wrapping_add(ap.plus(d));
            }
            black_box(acc);
        });
    }
}
