//! Fig. 2 regenerator (bench-scale): learning curves for the four series
//! (lin12/lin16/log12-lut/log16-lut) on the synthetic MNIST stand-in,
//! plus per-epoch wall-clock so the curves double as a training-throughput
//! benchmark. Full-scale regeneration: `cargo run --release -- fig2`.

use lnsdnn::coordinator::experiments::{fig2, ConfigTag, LogMode};
use lnsdnn::coordinator::{report, MultiprocSpec};
use lnsdnn::data::{synth_dataset, SynthSpec};
use std::path::Path;

fn main() {
    let ds = synth_dataset(&SynthSpec::mnist_like(0.02, 7));
    println!(
        "Fig. 2 (bench scale): {} — {} train / {} test, 8 epochs",
        ds.name,
        ds.train_len(),
        ds.test_len()
    );
    let t0 = std::time::Instant::now();
    let recs = fig2(&ds, 8, 100, 7, 4, 1, &MultiprocSpec::new(1));
    let wall = t0.elapsed().as_secs_f64();

    report::write_csv(
        Path::new("results/fig2_mnist_bench.csv"),
        &["dataset", "config", "epoch", "train_loss", "val_accuracy", "seconds"],
        &report::fig2_csv_rows(&recs),
    )
    .expect("write fig2 csv");

    println!("\n{:<12} {:>10} {:>12} {:>14}", "series", "final val", "test acc", "s/epoch (med)");
    for r in &recs {
        let mut secs: Vec<f64> = r.curve.iter().map(|e| e.seconds).collect();
        secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        println!(
            "{:<12} {:>9.3} {:>11.3} {:>13.2}s",
            r.tag.label(),
            r.curve.last().map(|e| e.val_accuracy).unwrap_or(0.0),
            r.test_accuracy,
            secs[secs.len() / 2]
        );
    }
    println!("\ntotal wall {wall:.1}s → results/fig2_mnist_bench.csv");

    // Paper-shape assertions: 16-bit tracks its linear twin; curves rise.
    let get = |t: ConfigTag| recs.iter().find(|r| r.tag == t).unwrap();
    let log16 = get(ConfigTag::Log(16, LogMode::Lut));
    let lin16 = get(ConfigTag::Lin(16));
    assert!(
        log16.test_accuracy > lin16.test_accuracy - 0.15,
        "log16 should track lin16: {} vs {}",
        log16.test_accuracy,
        lin16.test_accuracy
    );
    for r in &recs {
        let first = r.curve.first().unwrap().val_accuracy;
        let last = r.curve.last().unwrap().val_accuracy;
        assert!(last >= first - 0.05, "{}: curve should rise", r.tag.label());
    }
    println!("shape checks passed (log16 tracks lin16; curves rise)");
}
