//! Conv-subsystem microbenchmarks: im2col-lowered conv2d MAC/s per number
//! system, serial vs rayon row-parallel (forward and backward), plus
//! pooling throughput — the conv twin of `benches/ops.rs`, so the
//! speedups the lowering inherits from the row-parallel matmul engine are
//! measured, not asserted.

use lnsdnn::bench_util::{bench, black_box};
use lnsdnn::fixed::{FixedConfig, FixedSystem};
use lnsdnn::lns::{LnsConfig, LnsSystem};
use lnsdnn::nn::{Conv2d, InitScheme, Pool2d};
use lnsdnn::rng::SplitMix64;
use lnsdnn::tensor::{Backend, ConvShape, FixedBackend, FloatBackend, LnsBackend, Tensor};

/// Bench one backend's conv forward+backward, serial vs parallel.
fn conv_case<B: Backend>(label: &str, backend: &B) {
    // A LeNet-middle-layer shape: batch 32, 16×16×4 → 8 channels, 5×5
    // kernels, shape-preserving padding.
    let (batch, side, in_c, out_c) = (32usize, 16usize, 4usize, 8usize);
    let shape = ConvShape::square(in_c, side, 5, 1, 2);
    let mut rng = SplitMix64::new(11);
    let layer = Conv2d::init(backend, shape, out_c, InitScheme::HeNormal, &mut rng);
    let x = Tensor::from_vec(
        batch,
        shape.in_len(),
        (0..batch * shape.in_len()).map(|_| backend.encode(rng.uniform(-1.0, 1.0))).collect(),
    );
    // Forward MACs: one per (patch entry × output channel × patch).
    let macs = (batch * shape.patches_per_image() * shape.patch_len() * out_c) as f64;
    let s = bench(&format!("conv2d_fwd/{label} serial"), Some(macs), || {
        black_box(layer.forward_serial(backend, &x));
    });
    let p = bench(&format!("conv2d_fwd/{label} parallel"), Some(macs), || {
        black_box(layer.forward_par(backend, &x));
    });
    println!("    ↳ fwd speedup {:.2}×", s.median_ns / p.median_ns);

    // Backward (dW + dX lowered matmuls ≈ 2× forward MACs).
    let (cols, y) = layer.forward(backend, &x);
    let s = bench(&format!("conv2d_bwd/{label} serial"), Some(2.0 * macs), || {
        black_box(layer.backward_serial(backend, &cols, &y, true));
    });
    let p = bench(&format!("conv2d_bwd/{label} parallel"), Some(2.0 * macs), || {
        black_box(layer.backward_par(backend, &cols, &y, true));
    });
    println!("    ↳ bwd speedup {:.2}×", s.median_ns / p.median_ns);
}

fn main() {
    let threads = rayon::current_num_threads();
    println!("== conv subsystem microbenchmarks ({threads} threads) ==\n");
    conv_case("float32", &FloatBackend::default());
    conv_case("lin16", &FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01));
    conv_case("log16-lut", &LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01));
    conv_case("log16-bs", &LnsBackend::new(LnsSystem::new(LnsConfig::w16_bitshift()), 0.01));

    // Pooling: the log-domain compare path (integer compares in LNS).
    println!("\n-- pooling 64×(8ch 16×16), 2×2 --");
    let b = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
    let mut rng = SplitMix64::new(5);
    let pool = Pool2d::max(8, 16, 16, 2);
    let x = Tensor::from_vec(
        64,
        pool.in_len(),
        (0..64 * pool.in_len()).map(|_| b.encode(rng.uniform(-2.0, 2.0))).collect(),
    );
    bench("maxpool2x2/log16-lut", Some((64 * pool.out_len() * 4) as f64), || {
        black_box(pool.forward(&b, &x));
    });
    let avg = Pool2d::avg(8, 16, 16, 2);
    bench("avgpool2x2/log16-lut", Some((64 * avg.out_len() * 4) as f64), || {
        black_box(avg.forward(&b, &x));
    });
}
