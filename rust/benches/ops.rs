//! Op-level microbenchmarks: the paper's hardware argument, in software.
//!
//! Measures MAC throughput per number system (the paper's claim is that
//! LNS MACs need no multiplier; in software the LUT ⊞ costs a few integer
//! ops + a load — this bench quantifies that overhead against linear
//! fixed-point and float MACs) plus the Δ/softmax primitives, and — the
//! headline — serial vs rayon row-parallel vs cache-tiled matmul
//! throughput per backend (MAC/s and rows/s), so the parallel engine's
//! and the tiled kernels' speedups are measured, not asserted.
//!
//! The bench opens with the **pinned record suite**: fixed shapes and
//! seeds, one [`BenchRecord`] per (backend, kernel, shape), including the
//! lane-vs-scalar `mac_panel` pair that quantifies the branchless lane
//! kernels, the obs off/on pair that prices the telemetry gate, and the
//! `obs_serve` idle/scraped pair that prices a live `/metrics` scraper
//! (docs/OBSERVABILITY.md). CI runs it in quick mode and persists the records as the
//! repo's `BENCH_*.json` trajectory. Environment knobs:
//!
//! * `BENCH_QUICK=1` — record suite only, skip the exploratory sections,
//! * `BENCH_BUDGET_MS` — per-case budget (default 60 quick / 300 full),
//! * `BENCH_JSON_OUT`  — write the records to this path,
//! * `BENCH_COMMIT`    — commit field (falls back to `GITHUB_SHA`, then
//!   `"uncommitted"`),
//! * `BENCH_BASELINE`  — compare against this `BENCH_*.json` and emit
//!   `::warning ::` lines on >10 % drops (always exits 0 — throughput on
//!   shared CI runners is advisory, not a gate).

use lnsdnn::bench_util::{
    bench, bench_n, black_box, records_from_json, records_to_json, regressions, utc_date_string,
    BenchRecord,
};
use lnsdnn::fixed::{FixedConfig, FixedSystem};
use lnsdnn::lns::{lanes, DeltaMode, LnsConfig, LnsSystem, LnsValue};
use lnsdnn::obs;
use lnsdnn::rng::SplitMix64;
use lnsdnn::tensor::{ops, Backend, FixedBackend, FloatBackend, LnsBackend, Tensor};

const N: usize = 4096;

/// Accumulates the pinned suite's trajectory records with a shared
/// commit/date stamp.
struct Recorder {
    commit: String,
    date: String,
    records: Vec<BenchRecord>,
}

impl Recorder {
    fn new() -> Self {
        let commit = std::env::var("BENCH_COMMIT")
            .or_else(|_| std::env::var("GITHUB_SHA"))
            .unwrap_or_else(|_| "uncommitted".into());
        Recorder { commit, date: utc_date_string(), records: Vec::new() }
    }

    fn add(&mut self, backend: &str, kernel: &str, (m, k, n): (usize, usize, usize), tput: f64) {
        self.records.push(BenchRecord {
            commit: self.commit.clone(),
            date: self.date.clone(),
            backend: backend.into(),
            kernel: kernel.into(),
            shape: format!("{m}x{k}x{n}"),
            mac_per_s: tput,
        });
    }
}

/// Time one case with a single warm-up, print the report line, return
/// MAC/s.
fn timed<F: FnMut()>(label: &str, budget_ms: u64, macs: f64, f: F) -> f64 {
    let s = bench_n(label, 1, budget_ms, Some(macs), f);
    println!("{}", s.report());
    s.throughput().unwrap_or(0.0)
}

/// Record `matmul_tiled` throughput for one backend at one shape.
fn record_tiled<B: Backend>(
    rec: &mut Recorder,
    b: &B,
    (m, k, n): (usize, usize, usize),
    seed: u64,
    budget_ms: u64,
) {
    let (a, w) = encoded_mats(b, m, k, n, seed);
    let tag = b.tag();
    let label = format!("record/{tag}/matmul_tiled/{m}x{k}x{n}");
    let tput = timed(&label, budget_ms, (m * k * n) as f64, || {
        black_box(ops::matmul_tiled(b, &a, &w));
    });
    rec.add(&tag, "matmul_tiled", (m, k, n), tput);
}

/// Record the lane-vs-scalar `mac_panel` pair at 256³ for an LNS backend
/// by flipping the process-global lane toggle around the same tiled
/// matmul (both paths are bit-identical, so the toggle only moves time).
/// Returns the lane/scalar speedup.
fn record_lane_vs_scalar(rec: &mut Recorder, b: &LnsBackend, seed: u64, budget_ms: u64) -> f64 {
    let shape = (256usize, 256usize, 256usize);
    let (m, k, n) = shape;
    let (a, w) = encoded_mats(b, m, k, n, seed);
    let macs = (m * k * n) as f64;
    let tag = b.tag();
    lanes::set_enabled(true);
    let lane_label = format!("record/{tag}/mac_panel_lane/{m}x{k}x{n}");
    let lane = timed(&lane_label, budget_ms, macs, || {
        black_box(ops::matmul_tiled(b, &a, &w));
    });
    lanes::set_enabled(false);
    let scalar_label = format!("record/{tag}/mac_panel_scalar/{m}x{k}x{n}");
    let scalar = timed(&scalar_label, budget_ms, macs, || {
        black_box(ops::matmul_tiled(b, &a, &w));
    });
    lanes::set_enabled(true);
    rec.add(&tag, "mac_panel_lane", shape, lane);
    rec.add(&tag, "mac_panel_scalar", shape, scalar);
    let speedup = lane / scalar;
    println!("    ↳ lane vs scalar mac_panel {speedup:.2}×");
    speedup
}

/// Record the observation cost pair at 256³: the same tiled matmul with
/// counters disabled (the production path — one relaxed load per
/// dispatcher call, so `mac_panel_obs_off` should sit within noise of
/// the adjacent `mac_panel_lane` record and of the previous PR's
/// trajectory; the CI baseline comparison is the disabled-overhead pin)
/// and with counters enabled (routes through the counted scalar bodies,
/// so the expected cost is roughly the lane/scalar ratio above).
fn record_obs_pair(rec: &mut Recorder, b: &LnsBackend, seed: u64, budget_ms: u64) {
    let shape = (256usize, 256usize, 256usize);
    let (m, k, n) = shape;
    let (a, w) = encoded_mats(b, m, k, n, seed);
    let macs = (m * k * n) as f64;
    let tag = b.tag();
    obs::set_counters(false);
    let off_label = format!("record/{tag}/mac_panel_obs_off/{m}x{k}x{n}");
    let off = timed(&off_label, budget_ms, macs, || {
        black_box(ops::matmul_tiled(b, &a, &w));
    });
    obs::set_counters(true);
    let on_label = format!("record/{tag}/mac_panel_obs_on/{m}x{k}x{n}");
    let on = timed(&on_label, budget_ms, macs, || {
        black_box(ops::matmul_tiled(b, &a, &w));
    });
    obs::set_counters(false);
    obs::reset_all();
    rec.add(&tag, "mac_panel_obs_off", shape, off);
    rec.add(&tag, "mac_panel_obs_on", shape, on);
    println!("    ↳ counting cost {:.2}× (obs off vs on)", off / on);
}

/// Record the live-endpoint cost pair at 256³: the same tiled matmul
/// (counters on, so `/metrics` renders real content) with the HTTP
/// endpoint bound but idle, then with a scraper thread looping `GET
/// /metrics` for the whole measurement. The pair prices a worst-case
/// scrape storm; a real Prometheus scrape arrives every few seconds, so
/// the production cost sits between the two records and near the idle
/// one.
fn record_serve_pair(rec: &mut Recorder, b: &LnsBackend, seed: u64, budget_ms: u64) {
    use std::io::{Read, Write};
    use std::sync::atomic::{AtomicBool, Ordering};
    let shape = (256usize, 256usize, 256usize);
    let (m, k, n) = shape;
    let (a, w) = encoded_mats(b, m, k, n, seed);
    let macs = (m * k * n) as f64;
    let tag = b.tag();
    obs::set_counters(true);
    let srv = obs::serve::ObsServer::start("127.0.0.1:0").expect("bind bench obs endpoint");
    let addr = srv.addr();
    let idle_label = format!("record/{tag}/obs_serve_idle/{m}x{k}x{n}");
    let idle = timed(&idle_label, budget_ms, macs, || {
        black_box(ops::matmul_tiled(b, &a, &w));
    });
    let stop = std::sync::Arc::new(AtomicBool::new(false));
    let scraper = {
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut scrapes = 0u64;
            while !stop.load(Ordering::Relaxed) {
                if let Ok(mut s) = std::net::TcpStream::connect(addr) {
                    let _ = s.write_all(
                        b"GET /metrics HTTP/1.1\r\nHost: bench\r\nConnection: close\r\n\r\n",
                    );
                    let mut body = String::new();
                    let _ = s.read_to_string(&mut body);
                    scrapes += 1;
                }
            }
            scrapes
        })
    };
    let scraped_label = format!("record/{tag}/obs_serve_scraped/{m}x{k}x{n}");
    let scraped = timed(&scraped_label, budget_ms, macs, || {
        black_box(ops::matmul_tiled(b, &a, &w));
    });
    stop.store(true, Ordering::Relaxed);
    let scrapes = scraper.join().unwrap_or(0);
    srv.stop();
    obs::set_counters(false);
    obs::reset_all();
    rec.add(&tag, "obs_serve_idle", shape, idle);
    rec.add(&tag, "obs_serve_scraped", shape, scraped);
    println!("    ↳ scrape cost {:.2}× (idle vs scraped, {scrapes} scrapes)", idle / scraped);
}

/// The pinned record suite: 256³ on all four backends, the lane-vs-scalar
/// pairs on both LNS Δ modes plus the w8-vs-w16 width pair, the obs
/// off/on pair, the live-endpoint idle/scraped pair, and the MLP /
/// im2col shapes.
fn record_suite(budget_ms: u64) -> Vec<BenchRecord> {
    let mut rec = Recorder::new();
    let cube = (256usize, 256usize, 256usize);
    let lin = FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
    record_tiled(&mut rec, &FloatBackend::default(), cube, 21, budget_ms);
    record_tiled(&mut rec, &lin, cube, 21, budget_ms);
    let lut = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
    let bs = LnsBackend::new(LnsSystem::new(LnsConfig::w16_bitshift()), 0.01);
    record_tiled(&mut rec, &lut, cube, 21, budget_ms);
    record_tiled(&mut rec, &bs, cube, 21, budget_ms);
    record_lane_vs_scalar(&mut rec, &lut, 22, budget_ms);
    record_lane_vs_scalar(&mut rec, &bs, 22, budget_ms);
    // The w8-vs-w16 width pair (PR 10): the same tiled matmul and lane
    // toggle on the 8-bit word, so the trajectory shows what narrowing
    // the word buys (or costs) in software — in hardware the win is
    // area, but the soft-max LUT shrinks with the word too (640 → 40
    // entries at the q_f = 2 grid) and both Δ paths stay in cache.
    let lut8 = LnsBackend::new(LnsSystem::new(LnsConfig::w8_lut()), 0.01);
    record_tiled(&mut rec, &lut8, cube, 21, budget_ms);
    record_lane_vs_scalar(&mut rec, &lut8, 22, budget_ms);
    record_obs_pair(&mut rec, &lut, 22, budget_ms);
    record_serve_pair(&mut rec, &lut, 22, budget_ms);
    for shape in [(256usize, 784usize, 100usize), (6272, 150, 12)] {
        record_tiled(&mut rec, &FloatBackend::default(), shape, 23, budget_ms);
        record_tiled(&mut rec, &lut, shape, 23, budget_ms);
        record_tiled(&mut rec, &bs, shape, 23, budget_ms);
    }
    rec.records
}

fn lns_operands(sys: &LnsSystem, seed: u64) -> Vec<(LnsValue, LnsValue)> {
    let mut rng = SplitMix64::new(seed);
    (0..N)
        .map(|_| {
            (
                sys.encode_f64(rng.uniform(-8.0, 8.0)),
                sys.encode_f64(rng.uniform(-8.0, 8.0)),
            )
        })
        .collect()
}

fn main() {
    let quick = std::env::var("BENCH_QUICK").is_ok_and(|v| v == "1");
    let budget_ms = std::env::var("BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if quick { 60 } else { 300 });
    let mode = if quick { ", quick" } else { "" };
    println!("== pinned record suite ({budget_ms} ms/case{mode}) ==\n");
    let records = record_suite(budget_ms);
    if let Ok(path) = std::env::var("BENCH_JSON_OUT") {
        std::fs::write(&path, records_to_json(&records)).expect("write BENCH_JSON_OUT");
        println!("\nwrote {} records to {path}", records.len());
    }
    if let Ok(path) = std::env::var("BENCH_BASELINE") {
        match std::fs::read_to_string(&path).ok().and_then(|t| records_from_json(&t)) {
            Some(old) => {
                let hits = regressions(&records, &old, 0.10);
                if hits.is_empty() {
                    println!("baseline {path}: no kernel regressed > 10%");
                } else {
                    // Fail-soft: shared CI runners make throughput advisory.
                    for h in &hits {
                        println!("::warning ::bench regression vs {path}: {h}");
                    }
                }
            }
            None => println!("::warning ::could not read/parse baseline {path}"),
        }
    }
    if quick {
        return;
    }

    println!("\n== op-level microbenchmarks (N = {N} per iteration) ==\n");

    // MAC chains per number system.
    println!("-- MAC: acc = acc + a*b over {N} pairs --");
    for (label, mode) in [
        ("lns16 LUT(20)", DeltaMode::Lut(lnsdnn::lns::LutSpec::MAC20)),
        ("lns16 bit-shift", DeltaMode::BitShift),
        ("lns16 exact Δ (float libm)", DeltaMode::Exact),
    ] {
        let mut cfg = LnsConfig::w16_lut();
        cfg.delta = mode;
        cfg.softmax_delta = mode;
        let sys = LnsSystem::new(cfg);
        let pairs = lns_operands(&sys, 1);
        bench(&format!("mac/{label}"), Some(N as f64), || {
            let mut acc = LnsValue::ZERO;
            for &(a, b) in &pairs {
                acc = sys.mac(acc, a, b);
            }
            black_box(acc);
        });
    }
    {
        let sys = FixedSystem::new(FixedConfig::w16());
        let mut rng = SplitMix64::new(2);
        let pairs: Vec<(i32, i32)> = (0..N)
            .map(|_| {
                (sys.encode_f64(rng.uniform(-3.0, 3.0)), sys.encode_f64(rng.uniform(-3.0, 3.0)))
            })
            .collect();
        bench("mac/lin16 Q-format", Some(N as f64), || {
            let mut acc = 0i32;
            for &(a, b) in &pairs {
                acc = sys.mac(acc, a, b);
            }
            black_box(acc);
        });
    }
    {
        let mut rng = SplitMix64::new(3);
        let pairs: Vec<(f32, f32)> = (0..N)
            .map(|_| (rng.uniform(-3.0, 3.0) as f32, rng.uniform(-3.0, 3.0) as f32))
            .collect();
        bench("mac/float32", Some(N as f64), || {
            let mut acc = 0f32;
            for &(a, b) in &pairs {
                acc += a * b;
            }
            black_box(acc);
        });
    }

    // Matmul through the generic tensor path (the training hot loop).
    println!("\n-- matmul 32×784 · 784×100 (one fwd layer, batch 32) --");
    let dims = (32usize, 784usize, 100usize);
    {
        let b = FloatBackend::default();
        let (a, w) = float_mats(dims.0, dims.1, dims.2, 4);
        bench("matmul/float32", Some((dims.0 * dims.1 * dims.2) as f64), || {
            black_box(ops::matmul(&b, &a, &w));
        });
    }
    {
        let b = FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
        let (a, w) = encoded_mats(&b, dims.0, dims.1, dims.2, 5);
        bench("matmul/lin16", Some((dims.0 * dims.1 * dims.2) as f64), || {
            black_box(ops::matmul(&b, &a, &w));
        });
    }
    for (label, cfg) in [
        ("log16-lut", LnsConfig::w16_lut()),
        ("log16-bs", LnsConfig::w16_bitshift()),
    ] {
        let b = LnsBackend::new(LnsSystem::new(cfg), 0.01);
        let (a, w) = encoded_mats(&b, dims.0, dims.1, dims.2, 6);
        bench(&format!("matmul/{label}"), Some((dims.0 * dims.1 * dims.2) as f64), || {
            black_box(ops::matmul(&b, &a, &w));
        });
    }

    // Soft-max path.
    println!("\n-- log-softmax + CE grad, 26 classes × 64 rows --");
    let sys = LnsSystem::new(LnsConfig::w16_lut());
    let backend = LnsBackend::new(sys, 0.01);
    let mut rng = SplitMix64::new(7);
    let rows: Vec<Vec<LnsValue>> = (0..64)
        .map(|_| (0..26).map(|_| backend.encode(rng.uniform(-4.0, 4.0))).collect())
        .collect();
    let mut grad = vec![LnsValue::ZERO; 26];
    bench("softmax/log16-lut (640-entry table)", Some(64.0 * 26.0), || {
        for r in &rows {
            black_box(backend.softmax_ce_grad(r, 3, &mut grad));
        }
    });
    let fb = FloatBackend::default();
    let frows: Vec<Vec<f32>> = (0..64)
        .map(|_| (0..26).map(|_| rng.uniform(-4.0, 4.0) as f32).collect())
        .collect();
    let mut fgrad = vec![0f32; 26];
    bench("softmax/float32", Some(64.0 * 26.0), || {
        for r in &frows {
            black_box(fb.softmax_ce_grad(r, 3, &mut fgrad));
        }
    });

    // Serial vs rayon row-parallel matmul: the tentpole measurement.
    // Throughput column is MAC/s; the summary line adds rows/s and the
    // serial→parallel speedup on this machine.
    let threads = rayon::current_num_threads();
    println!("\n-- matmul 256×256×256, serial vs parallel ({threads} threads) --");
    let (m, k, n) = (256usize, 256usize, 256usize);
    let macs = (m * k * n) as f64;
    {
        let b = FloatBackend::default();
        let (a, w) = float_mats(m, k, n, 8);
        bench_pair(
            "matmul256/float32",
            macs,
            m,
            || black_box(ops::matmul_serial(&b, &a, &w)).len(),
            || black_box(ops::matmul_par(&b, &a, &w)).len(),
        );
    }
    {
        let b = FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
        let (a, w) = encoded_mats(&b, m, k, n, 9);
        bench_pair(
            "matmul256/lin16",
            macs,
            m,
            || black_box(ops::matmul_serial(&b, &a, &w)).len(),
            || black_box(ops::matmul_par(&b, &a, &w)).len(),
        );
    }
    for (label, cfg) in [
        ("log16-lut", LnsConfig::w16_lut()),
        ("log16-bs", LnsConfig::w16_bitshift()),
    ] {
        let b = LnsBackend::new(LnsSystem::new(cfg), 0.01);
        let (a, w) = encoded_mats(&b, m, k, n, 10);
        bench_pair(
            &format!("matmul256/{label}"),
            macs,
            m,
            || black_box(ops::matmul_serial(&b, &a, &w)).len(),
            || black_box(ops::matmul_par(&b, &a, &w)).len(),
        );
    }
    // The backward shapes for the LNS hot path.
    {
        let b = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
        let (a, w) = encoded_mats(&b, m, k, n, 11);
        let wt = w.transpose(); // [n,k] operand, materialized once
        bench_pair(
            "matmul256_bt/log16-lut",
            macs,
            m,
            || black_box(ops::matmul_bt_serial(&b, &a, &wt)).len(),
            || black_box(ops::matmul_bt_par(&b, &a, &wt)).len(),
        );
    }

    // Cache-tiled vs row-parallel: the blocked kernels pack `w` into
    // L1/L2-sized column panels while keeping every per-element ⊞ chain
    // k-ascending, so these lines measure pure locality — the results
    // are bit-identical by construction (tests/tiled_exactness.rs).
    // Reported at the ISSUE's three motivating shapes: 256³, the MLP
    // eval batch (B×784 · 784×100), and the im2col patch matrix of
    // lenet28's conv-2 at batch 32 (6272×150 · 150×12).
    println!("\n-- tiled vs row-parallel (tiles {:?}) --", ops::Tiling::DEFAULT);
    {
        let b = FloatBackend::default();
        let (a, w) = float_mats(m, k, n, 12);
        bench_tiled(
            "matmul256/float32",
            macs,
            || black_box(ops::matmul_par(&b, &a, &w)).len(),
            || black_box(ops::matmul_tiled(&b, &a, &w)).len(),
        );
    }
    {
        let b = FixedBackend::new(FixedSystem::new(FixedConfig::w16()), 0.01);
        let (a, w) = encoded_mats(&b, m, k, n, 13);
        bench_tiled(
            "matmul256/lin16",
            macs,
            || black_box(ops::matmul_par(&b, &a, &w)).len(),
            || black_box(ops::matmul_tiled(&b, &a, &w)).len(),
        );
    }
    for (label, cfg) in [
        ("log16-lut", LnsConfig::w16_lut()),
        ("log16-bs", LnsConfig::w16_bitshift()),
    ] {
        let b = LnsBackend::new(LnsSystem::new(cfg), 0.01);
        let (a, w) = encoded_mats(&b, m, k, n, 14);
        bench_tiled(
            &format!("matmul256/{label}"),
            macs,
            || black_box(ops::matmul_par(&b, &a, &w)).len(),
            || black_box(ops::matmul_tiled(&b, &a, &w)).len(),
        );
        // The backward shapes at 256³ for the LNS hot path.
        let wt = w.transpose();
        bench_tiled(
            &format!("matmul256_bt/{label}"),
            macs,
            || black_box(ops::matmul_bt_par(&b, &a, &wt)).len(),
            || black_box(ops::matmul_bt_tiled(&b, &a, &wt)).len(),
        );
        let at = a.transpose();
        bench_tiled(
            &format!("matmul256_at/{label}"),
            macs,
            || black_box(ops::matmul_at_par(&b, &at, &w)).len(),
            || black_box(ops::matmul_at_tiled(&b, &at, &w)).len(),
        );
    }
    // MLP eval batch: 256×784 · 784×100 (the 784-wide layer the tiles
    // were sized for).
    let (bm, bk, bn) = (256usize, 784usize, 100usize);
    let mlp_macs = (bm * bk * bn) as f64;
    {
        let b = FloatBackend::default();
        let (a, w) = float_mats(bm, bk, bn, 15);
        bench_tiled(
            "matmul_mlp 256×784·784×100/float32",
            mlp_macs,
            || black_box(ops::matmul_par(&b, &a, &w)).len(),
            || black_box(ops::matmul_tiled(&b, &a, &w)).len(),
        );
    }
    {
        let b = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
        let (a, w) = encoded_mats(&b, bm, bk, bn, 16);
        bench_tiled(
            "matmul_mlp 256×784·784×100/log16-lut",
            mlp_macs,
            || black_box(ops::matmul_par(&b, &a, &w)).len(),
            || black_box(ops::matmul_tiled(&b, &a, &w)).len(),
        );
    }
    // im2col patch matrix: lenet28 conv-2 at batch 32 lowers to
    // 6272×150 · 150×12 (B·OH·OW = 32·14·14 patch rows).
    let (pm, pk, pn) = (6272usize, 150usize, 12usize);
    let patch_macs = (pm * pk * pn) as f64;
    {
        let b = FloatBackend::default();
        let (a, w) = float_mats(pm, pk, pn, 17);
        bench_tiled(
            "matmul_im2col 6272×150·150×12/float32",
            patch_macs,
            || black_box(ops::matmul_par(&b, &a, &w)).len(),
            || black_box(ops::matmul_tiled(&b, &a, &w)).len(),
        );
    }
    {
        let b = LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01);
        let (a, w) = encoded_mats(&b, pm, pk, pn, 18);
        bench_tiled(
            "matmul_im2col 6272×150·150×12/log16-lut",
            patch_macs,
            || black_box(ops::matmul_par(&b, &a, &w)).len(),
            || black_box(ops::matmul_tiled(&b, &a, &w)).len(),
        );
    }
}

/// Random float operand pair `[m,k]·[k,n]`.
fn float_mats(m: usize, k: usize, n: usize, seed: u64) -> (Tensor<f32>, Tensor<f32>) {
    let mut rng = SplitMix64::new(seed);
    let a = Tensor::from_vec(m, k, (0..m * k).map(|_| rng.uniform(-1.0, 1.0) as f32).collect());
    let w = Tensor::from_vec(k, n, (0..k * n).map(|_| rng.uniform(-1.0, 1.0) as f32).collect());
    (a, w)
}

/// Random encoded operand pair `[m,k]·[k,n]` for any backend.
fn encoded_mats<B: Backend>(
    b: &B,
    m: usize,
    k: usize,
    n: usize,
    seed: u64,
) -> (Tensor<B::E>, Tensor<B::E>) {
    let mut rng = SplitMix64::new(seed);
    let a = Tensor::from_vec(m, k, (0..m * k).map(|_| b.encode(rng.uniform(-1.0, 1.0))).collect());
    let w = Tensor::from_vec(k, n, (0..k * n).map(|_| b.encode(rng.uniform(-1.0, 1.0))).collect());
    (a, w)
}

/// Bench the row-parallel and cache-tiled variants of one case and print
/// the tiled-vs-row speedup summary line (throughput column is MAC/s).
fn bench_tiled<FR: FnMut() -> usize, FT: FnMut() -> usize>(
    label: &str,
    macs: f64,
    mut row: FR,
    mut tiled: FT,
) {
    let r = lnsdnn::bench_util::bench(&format!("{label} row-par"), Some(macs), || {
        black_box(row());
    });
    let t = lnsdnn::bench_util::bench(&format!("{label} tiled"), Some(macs), || {
        black_box(tiled());
    });
    println!("    ↳ tiled vs row-par {:.2}×", r.median_ns / t.median_ns);
}

/// Bench the serial and parallel variants of one case and print the
/// speedup + rows/s summary line.
fn bench_pair<FS: FnMut() -> usize, FP: FnMut() -> usize>(
    label: &str,
    macs: f64,
    rows: usize,
    mut serial: FS,
    mut parallel: FP,
) {
    let s = lnsdnn::bench_util::bench(&format!("{label} serial"), Some(macs), || {
        black_box(serial());
    });
    let p = lnsdnn::bench_util::bench(&format!("{label} parallel"), Some(macs), || {
        black_box(parallel());
    });
    let speedup = s.median_ns / p.median_ns;
    println!(
        "    ↳ speedup {speedup:.2}×   rows/s {:.0} → {:.0}",
        rows as f64 / (s.median_ns * 1e-9),
        rows as f64 / (p.median_ns * 1e-9)
    );
}
