//! Table 1 regenerator (bench scale): all seven number-system columns on
//! all four synthetic paper datasets, with shape assertions mirroring the
//! paper's qualitative claims. Full-scale: `cargo run --release -- table1
//! --scale 1.0 --epochs 20`.

use lnsdnn::coordinator::experiments::{table1, ConfigTag, LogMode};
use lnsdnn::coordinator::{report, MultiprocSpec};
use lnsdnn::data::paper_datasets;
use std::path::Path;

fn main() {
    let datasets = paper_datasets(0.015, 7);
    println!("Table 1 (bench scale 0.015, 6 epochs, hidden 48):");
    for d in &datasets {
        println!(
            "  {}: {} train / {} test, {} classes",
            d.name,
            d.train_len(),
            d.test_len(),
            d.classes
        );
    }
    let t0 = std::time::Instant::now();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let recs = table1(&datasets, 6, 48, 7, threads, 1, &MultiprocSpec::new(1));
    let wall = t0.elapsed().as_secs_f64();

    let md = report::table1_markdown(&recs);
    report::write_markdown(Path::new("results/table1_bench.md"), &md).unwrap();
    report::write_csv(
        Path::new("results/table1_bench.csv"),
        &["dataset", "config", "test_accuracy", "test_loss", "seconds"],
        &report::runs_csv_rows(&recs),
    )
    .unwrap();
    println!("\n{md}");
    println!("total wall {wall:.1}s → results/table1_bench.{{md,csv}}");

    // The paper's qualitative claims, asserted per dataset:
    //   (a) 16-bit log-LUT within a small gap of float;
    //   (b) LUT ≥ bit-shift at matched width (allowing small-task noise);
    //   (c) 16-bit ≥ 12-bit within the log-LUT family.
    let acc = |d: &str, t: ConfigTag| {
        recs.iter()
            .find(|r| r.dataset == d && r.tag == t)
            .map(|r| r.test_accuracy)
            .unwrap()
    };
    let mut claims_ok = 0;
    let mut claims = 0;
    for d in ["mnist", "fmnist", "emnistd", "emnistl"] {
        let float = acc(d, ConfigTag::Float);
        let l16 = acc(d, ConfigTag::Log(16, LogMode::Lut));
        let l12 = acc(d, ConfigTag::Log(12, LogMode::Lut));
        let b16 = acc(d, ConfigTag::Log(16, LogMode::Bs));
        claims += 3;
        claims_ok += (l16 > float - 0.12) as i32;
        claims_ok += (l16 > b16 - 0.06) as i32;
        claims_ok += (l16 > l12 - 0.06) as i32;
        println!(
            "  {d}: float {float:.3}  log16-lut {l16:.3}  log12-lut {l12:.3}  log16-bs {b16:.3}"
        );
    }
    println!("shape claims holding: {claims_ok}/{claims}");
    assert!(
        claims_ok as f64 >= claims as f64 * 0.75,
        "paper-shape claims should mostly hold at bench scale"
    );
}
