//! Shard-scaling throughput: training samples/s vs worker count for the
//! MLP and CNN workloads, per number system. The trained weights are
//! bit-identical at every shard count (tests/shard_determinism.rs), so
//! this bench measures the *only* thing the `shards` axis is allowed to
//! move: wall-clock.
//!
//! Timing uses the epoch records' step seconds (training steps only —
//! evaluation and encoding are excluded), mirroring how the paper-scale
//! sweeps report throughput.

use lnsdnn::data::{stripes_dataset, synth_dataset, StripeSpec, SynthSpec};
use lnsdnn::lns::{LnsConfig, LnsSystem};
use lnsdnn::nn::SgdConfig;
use lnsdnn::tensor::{Backend, FloatBackend, LnsBackend};
use lnsdnn::train::{train, train_cnn, CnnTrainConfig, ShardConfig, TrainConfig};

const SHARD_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// Samples that actually enter training steps (after the 1:val_ratio
/// validation hold-back).
fn trained_samples(total: usize, val_ratio: usize, epochs: usize) -> f64 {
    ((total - total / val_ratio) * epochs) as f64
}

/// Run a measurement with exactly `workers` threads available: sharded
/// runs bring their own `n_shards`-thread pool, while the `n = 1`
/// baseline is pinned to a 1-thread pool so the global rayon pool cannot
/// quietly parallelize it — "x vs serial" then honestly compares
/// N workers against one.
fn with_workers<R, F>(workers: usize, f: F) -> R
where
    R: Send,
    F: FnOnce() -> R + Send,
{
    if workers == 1 {
        let one = rayon::ThreadPoolBuilder::new()
            .num_threads(1)
            .build()
            .expect("building the 1-thread baseline pool");
        one.install(f)
    } else {
        f()
    }
}

fn mlp_case<B: Backend>(label: &str, backend: &B) {
    let ds = synth_dataset(&SynthSpec {
        name: "bench".into(),
        classes: 4,
        train_per_class: 60,
        test_per_class: 10,
        strokes: 4,
        jitter_px: 1.5,
        jitter_rot: 0.15,
        noise: 0.04,
        seed: 7,
    });
    let mut base = 0.0f64;
    for n in SHARD_COUNTS {
        let cfg = TrainConfig {
            dims: vec![784, 64, 4],
            epochs: 2,
            batch_size: 32,
            sgd: SgdConfig { lr: 0.02, weight_decay: 0.0 },
            val_ratio: 5,
            init: lnsdnn::nn::InitScheme::HeNormal,
            seed: 5,
            shard: ShardConfig::with_shards(n),
            precision: lnsdnn::precision::PrecisionMap::uniform(),
        };
        let r = with_workers(n, || train(backend, &ds, &cfg));
        let secs: f64 = r.curve.iter().map(|e| e.seconds).sum();
        let sps = trained_samples(ds.train_len(), cfg.val_ratio, cfg.epochs) / secs;
        if n == 1 {
            base = sps;
        }
        println!(
            "mlp/{label:<10} shards={n}  {sps:>10.0} samples/s  ({:.2}x vs serial)",
            sps / base
        );
    }
}

fn cnn_case<B: Backend>(label: &str, backend: &B) {
    let ds = stripes_dataset(&StripeSpec {
        train_per_class: 40,
        test_per_class: 8,
        ..StripeSpec::cnn_default(1.0, 7)
    });
    let mut base = 0.0f64;
    for n in SHARD_COUNTS {
        let mut cfg = CnnTrainConfig::lenet(12, 4);
        cfg.arch.c1 = 4;
        cfg.arch.c2 = 8;
        cfg.arch.hidden = 32;
        cfg.epochs = 1;
        cfg.batch_size = 32;
        cfg.sgd = SgdConfig { lr: 0.02, weight_decay: 0.0 };
        cfg.seed = 5;
        cfg.shard = ShardConfig::with_shards(n);
        let r = with_workers(n, || train_cnn(backend, &ds, &cfg));
        let secs: f64 = r.curve.iter().map(|e| e.seconds).sum();
        let sps = trained_samples(ds.train_len(), cfg.val_ratio, cfg.epochs) / secs;
        if n == 1 {
            base = sps;
        }
        println!(
            "cnn/{label:<10} shards={n}  {sps:>10.0} samples/s  ({:.2}x vs serial)",
            sps / base
        );
    }
}

fn main() {
    println!(
        "== shard scaling (host parallelism {}) ==\n",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    mlp_case("float32", &FloatBackend::default());
    mlp_case("log16-lut", &LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01));
    println!();
    cnn_case("float32", &FloatBackend::default());
    cnn_case("log16-lut", &LnsBackend::new(LnsSystem::new(LnsConfig::w16_lut()), 0.01));
    println!("\nweights are bit-identical across shard counts; only wall-clock moves.");
}
