"""L1 kernel vs oracle: the CORE correctness signal.

The Pallas `lns_matmul` must be **bit-exact** against the pure-jnp
oracle `ref.matmul_ref` for every config, shape and operand pattern —
hypothesis sweeps shapes/values; fixed cases pin the paper's dims.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import lnscore as lc
from compile.kernels import ref
from compile.kernels.lns_matmul import lns_matmul


CFGS = {c.name: c for c in [lc.w16_lut(), lc.w12_lut(), lc.w16_bitshift(), lc.w12_bitshift()]}


def random_lns(rng, cfg, shape, zero_frac=0.1):
    m = rng.integers(cfg.m_min, cfg.m_max + 1, size=shape).astype(np.int32)
    z = rng.random(shape) < zero_frac
    m = np.where(z, lc.ZERO_M, m).astype(np.int32)
    s = rng.integers(0, 2, size=shape).astype(np.int32)
    s = np.where(z, 1, s).astype(np.int32)
    return jnp.asarray(m), jnp.asarray(s)


def assert_bitexact(cfg_name, b, k, n, seed, zero_frac=0.1):
    cfg = CFGS[cfg_name]
    tables = lc.delta_tables(cfg, "mac")
    rng = np.random.default_rng(seed)
    am, as_ = random_lns(rng, cfg, (b, k), zero_frac)
    wm, ws = random_lns(rng, cfg, (k, n), zero_frac)
    km, ks = lns_matmul(am, as_, wm, ws, cfg, tables)
    rm, rs = ref.matmul_ref(am, as_, wm, ws, cfg, tables)
    np.testing.assert_array_equal(np.asarray(km), np.asarray(rm), err_msg="magnitudes differ")
    # Signs only matter for non-zero outputs.
    nz = np.asarray(km) != lc.ZERO_M
    np.testing.assert_array_equal(np.asarray(ks)[nz], np.asarray(rs)[nz], err_msg="signs differ")


@pytest.mark.parametrize("cfg_name", list(CFGS))
def test_kernel_bitexact_small(cfg_name):
    assert_bitexact(cfg_name, 3, 7, 5, seed=1)


@pytest.mark.parametrize("cfg_name", ["w16_lut", "w12_bs"])
def test_kernel_bitexact_paper_layer_shape(cfg_name):
    # The paper's hidden layer (batch 5): 5×784 · 784×100.
    assert_bitexact(cfg_name, 5, 784, 100, seed=2)


def test_kernel_bitexact_all_zero_inputs():
    cfg = CFGS["w16_lut"]
    tables = lc.delta_tables(cfg, "mac")
    am = jnp.full((2, 4), lc.ZERO_M, jnp.int32)
    as_ = jnp.ones((2, 4), jnp.int32)
    wm, ws = random_lns(np.random.default_rng(0), cfg, (4, 3))
    km, ks = lns_matmul(am, as_, wm, ws, cfg, tables)
    assert np.all(np.asarray(km) == lc.ZERO_M)
    rm, _ = ref.matmul_ref(am, as_, wm, ws, cfg, tables)
    np.testing.assert_array_equal(np.asarray(km), np.asarray(rm))


@settings(max_examples=25, deadline=None)
@given(
    cfg_name=st.sampled_from(list(CFGS)),
    b=st.integers(1, 8),
    k=st.integers(1, 32),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
    zero_frac=st.sampled_from([0.0, 0.1, 0.5, 0.9]),
)
def test_kernel_bitexact_hypothesis(cfg_name, b, k, n, seed, zero_frac):
    assert_bitexact(cfg_name, b, k, n, seed, zero_frac)


def test_kernel_matches_float_matmul_loosely():
    """Semantic sanity: LNS matmul ≈ float matmul for benign values."""
    cfg = CFGS["w16_lut"]
    tables = lc.delta_tables(cfg, "mac")
    rng = np.random.default_rng(7)
    a = rng.uniform(0.1, 2.0, (4, 16))
    w = rng.uniform(0.1, 2.0, (16, 3))
    am, as_ = (jnp.asarray(v) for v in lc.encode(a, cfg))
    wm, ws = (jnp.asarray(v) for v in lc.encode(w, cfg))
    km, ks = lns_matmul(am, as_, wm, ws, cfg, tables)
    got = lc.decode(np.asarray(km), np.asarray(ks), cfg)
    want = a @ w
    # Same-sign accumulation: LUT error compounds but stays bounded.
    np.testing.assert_allclose(got, want, rtol=0.25)


def test_blockspec_tiling_matches_untiled():
    """Different block shapes must not change the numbers (the grid only
    partitions the output; each tile reduces the full K)."""
    cfg = CFGS["w16_lut"]
    tables = lc.delta_tables(cfg, "mac")
    rng = np.random.default_rng(11)
    am, as_ = random_lns(rng, cfg, (8, 24))
    wm, ws = random_lns(rng, cfg, (24, 12))
    base = lns_matmul(am, as_, wm, ws, cfg, tables, block_m=8, block_n=12)
    for bm, bn in [(1, 12), (8, 4), (2, 6), (4, 3)]:
        out = lns_matmul(am, as_, wm, ws, cfg, tables, block_m=bm, block_n=bn)
        np.testing.assert_array_equal(np.asarray(base[0]), np.asarray(out[0]))
        np.testing.assert_array_equal(np.asarray(base[1]), np.asarray(out[1]))
