"""L2 model tests: shapes, learning behaviour, pallas/oracle agreement."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model as M
from compile.kernels import lnscore as lc


def small_spec(cfg_name="w16_lut", use_pallas=True, **kw):
    cfg = lc.BY_NAME[cfg_name]()
    defaults = dict(cfg=cfg, dims=(12, 8, 4), batch=3, lr=0.05, weight_decay=0.0)
    defaults.update(kw)
    return M.LnsModelSpec(use_pallas=use_pallas, **defaults)


def random_input(spec, seed=0, batch=None):
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.0, 1.0, ((batch or spec.batch), spec.dims[0]))
    xm, xs = lc.encode(x, spec.cfg)
    return jnp.asarray(xm), jnp.asarray(xs)


class TestForward:
    def test_logits_shape(self):
        spec = small_spec()
        params = M.init_params(spec, seed=1)
        xm, xs = random_input(spec)
        m, s = M.lns_logits(spec, params, xm, xs)
        assert m.shape == (3, 4)
        assert s.shape == (3, 4)
        assert m.dtype == jnp.int32

    def test_pallas_and_oracle_forward_bitexact(self):
        sp = small_spec(use_pallas=True)
        so = small_spec(use_pallas=False)
        params = M.init_params(sp, seed=2)
        xm, xs = random_input(sp, seed=3)
        mp, spg = M.lns_logits(sp, params, xm, xs)
        mo, sog = M.lns_logits(so, params, xm, xs)
        np.testing.assert_array_equal(np.asarray(mp), np.asarray(mo))
        nz = np.asarray(mp) != lc.ZERO_M
        np.testing.assert_array_equal(np.asarray(spg)[nz], np.asarray(sog)[nz])

    def test_param_names_order(self):
        names = M.param_names((12, 8, 4))
        assert names == ["w0m", "w0s", "b0m", "b0s", "w1m", "w1s", "b1m", "b1s"]


class TestTrainStep:
    def test_returns_updated_params_and_loss(self):
        spec = small_spec()
        params = M.init_params(spec, seed=4)
        xm, xs = random_input(spec, seed=5)
        labels = jnp.asarray(np.array([0, 1, 2], np.int32))
        new_params, log2p = M.lns_train_step(spec, params, xm, xs, labels)
        assert len(new_params) == len(params)
        assert log2p.shape == (3,)
        # Parameters must actually move.
        moved = any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(params, new_params)
        )
        assert moved

    def test_loss_decreases_over_repeated_steps(self):
        import jax

        spec = small_spec()
        params = M.init_params(spec, seed=6)
        xm, xs = random_input(spec, seed=7)
        labels = jnp.asarray(np.array([0, 1, 2], np.int32))
        step = jax.jit(M.make_lns_train_fn(spec))

        def mean_nll(log2p):
            return -float(np.mean(np.asarray(log2p))) / (1 << spec.cfg.frac_bits)

        out = step(*params, xm, xs, labels)
        lp0 = out[-1]
        params = list(out[:-1])
        for _ in range(30):
            out = step(*params, xm, xs, labels)
            params, lp = list(out[:-1]), out[-1]
        assert mean_nll(lp) < mean_nll(lp0) * 0.7, (mean_nll(lp0), mean_nll(lp))

    @pytest.mark.parametrize("cfg_name", ["w12_lut", "w16_bs"])
    def test_other_configs_step_without_error(self, cfg_name):
        spec = small_spec(cfg_name)
        params = M.init_params(spec, seed=8)
        xm, xs = random_input(spec, seed=9)
        labels = jnp.asarray(np.array([1, 2, 3], np.int32))
        new_params, _ = M.lns_train_step(spec, params, xm, xs, labels)
        assert len(new_params) == 8


class TestFloatBaseline:
    def test_float_train_learns(self):
        dims = (12, 8, 4)
        params = M.float_init(dims, seed=0)
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.uniform(0, 1, (8, 12)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, 4, 8), jnp.int32)
        _, l0 = M.float_train_step(params, x, labels, lr=0.1)
        for _ in range(60):
            params, loss = M.float_train_step(params, x, labels, lr=0.1)
        assert float(loss) < float(l0) * 0.5

    def test_lns_step_tracks_float_step_direction(self):
        """After one step on the same batch, LNS loss change should have the
        same sign as float (both decrease) — a loose semantic check."""
        spec = small_spec(lr=0.1)
        params = M.init_params(spec, seed=10)
        xm, xs = random_input(spec, seed=11)
        labels = jnp.asarray(np.array([0, 1, 2], np.int32))
        _, lp_before = M.lns_train_step(spec, params, xm, xs, labels)
        p2, _ = M.lns_train_step(spec, params, xm, xs, labels)
        for _ in range(10):
            p2, lp_after = M.lns_train_step(spec, p2, xm, xs, labels)
        assert float(np.mean(np.asarray(lp_after))) > float(np.mean(np.asarray(lp_before)))
