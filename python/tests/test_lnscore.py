"""Unit tests for the LNS core ops (Python side of the numeric spec)."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile.kernels import lnscore as lc


CFGS = [lc.w16_lut(), lc.w12_lut(), lc.w16_bitshift(), lc.w12_bitshift()]


@pytest.fixture(params=CFGS, ids=lambda c: c.name)
def cfg(request):
    return request.param


def tables(cfg):
    return lc.delta_tables(cfg, "mac")


def enc1(v, cfg):
    m, s = lc.encode(np.array([v]), cfg)
    return m, s


def dec1(m, s, cfg):
    return float(lc.decode(np.asarray(m), np.asarray(s), cfg)[0])


class TestEncodeDecode:
    def test_roundtrip_error_bounded(self, cfg):
        tol = 2.0 ** (0.5 / (1 << cfg.frac_bits)) - 1.0 + 1e-9
        for v in [1.0, -1.0, 3.25, -0.001, 123.456, 0.015, -7.0]:
            m, s = enc1(v, cfg)
            back = dec1(m, s, cfg)
            assert abs((back - v) / v) <= tol

    def test_zero_is_sentinel(self, cfg):
        m, s = enc1(0.0, cfg)
        assert m[0] == lc.ZERO_M
        assert dec1(m, s, cfg) == 0.0

    def test_saturation(self, cfg):
        m, _ = enc1(1e30, cfg)
        assert m[0] == cfg.m_max
        m, _ = enc1(1e-30, cfg)
        assert m[0] == cfg.m_min

    def test_word_layouts_match_paper(self):
        assert lc.w16_lut().m_max == (1 << 14) - 1
        assert lc.w12_lut().m_max == (1 << 10) - 1
        assert lc.w16_lut().frac_bits == 10
        assert lc.w12_lut().frac_bits == 6


class TestTables:
    def test_mac_lut_sizes(self, cfg):
        plus, minus, shift = tables(cfg)
        if cfg.delta_mode == "lut":
            assert plus.shape == (20,)
            assert minus.shape == (20,)
            assert shift == cfg.frac_bits - 1
        else:
            assert plus.shape == (0,)

    def test_softmax_lut_size(self):
        plus, minus, _ = lc.delta_tables(lc.w16_lut(), "softmax")
        assert plus.shape == (640,)
        assert minus[0] == lc.MINUS_SAT

    def test_delta_plus_at_zero_is_one(self, cfg):
        plus, _, _ = tables(cfg)
        if cfg.delta_mode == "lut":
            assert plus[0] == (1 << cfg.frac_bits)  # log2(2) = 1

    def test_pow2_table(self, cfg):
        entries, k = lc.pow2_table(cfg)
        assert entries.shape == (1 << k,)
        assert entries[0] == (1 << cfg.frac_bits)
        assert np.all(np.diff(entries) >= 0)


class TestMul:
    def test_powers_of_two_exact(self, cfg):
        t = tables(cfg)
        del t
        mx, sx = enc1(2.0, cfg)
        my, sy = enc1(4.0, cfg)
        om, os_ = lc.lns_mul(jnp.asarray(mx), jnp.asarray(sx), jnp.asarray(my), jnp.asarray(sy), cfg)
        assert dec1(np.asarray(om), np.asarray(os_), cfg) == 8.0

    def test_zero_annihilates(self, cfg):
        mx, sx = enc1(5.0, cfg)
        mz, sz = enc1(0.0, cfg)
        om, _ = lc.lns_mul(jnp.asarray(mx), jnp.asarray(sx), jnp.asarray(mz), jnp.asarray(sz), cfg)
        assert np.asarray(om)[0] == lc.ZERO_M

    def test_sign_rules(self, cfg):
        for (a, b, expect_pos) in [(2.0, 3.0, True), (-2.0, 3.0, False), (-2.0, -3.0, True)]:
            ma, sa = enc1(a, cfg)
            mb, sb = enc1(b, cfg)
            _, os_ = lc.lns_mul(jnp.asarray(ma), jnp.asarray(sa), jnp.asarray(mb), jnp.asarray(sb), cfg)
            assert (np.asarray(os_)[0] == 1) == expect_pos


class TestAdd:
    def test_zero_identity(self, cfg):
        t = tables(cfg)
        mx, sx = enc1(-0.4, cfg)
        mz, sz = enc1(0.0, cfg)
        om, os_ = lc.lns_add(jnp.asarray(mx), jnp.asarray(sx), jnp.asarray(mz), jnp.asarray(sz), cfg, t)
        assert np.asarray(om)[0] == mx[0]
        assert np.asarray(os_)[0] == sx[0]

    def test_exact_cancellation(self, cfg):
        t = tables(cfg)
        mx, sx = enc1(2.75, cfg)
        om, _ = lc.lns_add(jnp.asarray(mx), jnp.asarray(sx), jnp.asarray(mx), jnp.asarray(1 - sx), cfg, t)
        assert np.asarray(om)[0] == lc.ZERO_M

    def test_same_sign_close_to_real(self):
        cfg = lc.w16_lut()
        t = tables(cfg)
        for (a, b) in [(3.0, 1.5), (0.1, 0.1), (10.0, 0.25), (-2.0, -6.0)]:
            ma, sa = enc1(a, cfg)
            mb, sb = enc1(b, cfg)
            om, os_ = lc.lns_add(jnp.asarray(ma), jnp.asarray(sa), jnp.asarray(mb), jnp.asarray(sb), cfg, t)
            got = dec1(np.asarray(om), np.asarray(os_), cfg)
            assert abs((got - (a + b)) / (a + b)) < 0.12

    def test_commutative(self, cfg):
        t = tables(cfg)
        rng = np.random.default_rng(3)
        mx, sx = lc.encode(rng.uniform(-4, 4, 64), cfg)
        my, sy = lc.encode(rng.uniform(-4, 4, 64), cfg)
        a = lc.lns_add(jnp.asarray(mx), jnp.asarray(sx), jnp.asarray(my), jnp.asarray(sy), cfg, t)
        b = lc.lns_add(jnp.asarray(my), jnp.asarray(sy), jnp.asarray(mx), jnp.asarray(sx), cfg, t)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))

    def test_sub_is_add_of_negation(self, cfg):
        t = tables(cfg)
        mx, sx = enc1(3.0, cfg)
        my, sy = enc1(1.0, cfg)
        om, os_ = lc.lns_sub(jnp.asarray(mx), jnp.asarray(sx), jnp.asarray(my), jnp.asarray(sy), cfg, t)
        got = dec1(np.asarray(om), np.asarray(os_), cfg)
        assert abs(got - 2.0) < 0.3


class TestActivationSoftmax:
    def test_llrelu_positive_passthrough(self, cfg):
        beta = int(cfg.to_units(np.log2(0.01)))
        m, s = enc1(3.0, cfg)
        om, os_ = lc.llrelu(jnp.asarray(m), jnp.asarray(s), cfg, beta)
        assert np.asarray(om)[0] == m[0]
        assert np.asarray(os_)[0] == 1

    def test_llrelu_negative_scales_by_slope(self):
        cfg = lc.w16_lut()
        beta = int(cfg.to_units(np.log2(0.01)))
        m, s = enc1(-2.0, cfg)
        om, os_ = lc.llrelu(jnp.asarray(m), jnp.asarray(s), cfg, beta)
        got = dec1(np.asarray(om), np.asarray(os_), cfg)
        assert abs(got - (-0.02)) < 0.001

    def test_softmax_logit_units_tracks_float(self):
        cfg = lc.w16_lut()
        p2 = lc.pow2_table(cfg)
        for a in [-4.0, -0.5, 0.3, 2.0, 5.5]:
            m, s = enc1(a, cfg)
            t = int(np.asarray(lc.softmax_logit_units(jnp.asarray(m), jnp.asarray(s), cfg, p2))[0])
            want = a * np.log2(np.e) * (1 << cfg.frac_bits)
            assert abs(t - want) <= max(abs(want) * 0.004, 2.0), (a, t, want)

    def test_softmax_grad_close_to_float(self):
        cfg = lc.w16_lut()
        sm = lc.delta_tables(cfg, "softmax")
        p2 = lc.pow2_table(cfg)
        logits = np.array([[1.0, -0.5, 0.25, 2.0]])
        label = np.array([3], dtype=np.int32)
        lm, ls = lc.encode(logits, cfg)
        dm, dsn, lp = lc.log_softmax_ce_grad(
            jnp.asarray(lm), jnp.asarray(ls), jnp.asarray(label), cfg, sm, p2
        )
        d = lc.decode(np.asarray(dm), np.asarray(dsn), cfg)
        e = np.exp(logits[0])
        p = e / e.sum()
        want = p - np.eye(4)[3]
        np.testing.assert_allclose(d[0], want, atol=0.03)
        log2p = float(np.asarray(lp)[0]) / (1 << cfg.frac_bits)
        assert abs(log2p - np.log2(p[3])) < 0.05

    def test_softmax_grad_rows_sum_near_zero(self, cfg):
        sm = lc.delta_tables(cfg, "softmax")
        p2 = lc.pow2_table(cfg)
        rng = np.random.default_rng(5)
        logits = rng.uniform(-2, 2, (3, 6))
        lm, ls = lc.encode(logits, cfg)
        labels = np.array([0, 3, 5], dtype=np.int32)
        dm, dsn, _ = lc.log_softmax_ce_grad(
            jnp.asarray(lm), jnp.asarray(ls), jnp.asarray(labels), cfg, sm, p2
        )
        d = lc.decode(np.asarray(dm), np.asarray(dsn), cfg)
        # 12-bit words quantize coarsely and the bit-shift Δ− is a crude
        # approximation (the very effect behind the paper's lower bit-shift
        # accuracies); the probe is structural.
        tol = 0.06 if (cfg.total_bits == 16 and cfg.delta_mode == "lut") else 0.3
        assert np.all(np.abs(d.sum(axis=1)) < tol)
