"""Pure-jnp correctness oracle for the LNS kernels.

``matmul_ref`` reduces **sequentially over k ascending with the
accumulator as the left ⊞ operand** — the documented reduction order of
DESIGN.md §5 that the Rust engine and the Pallas kernel both follow.
Also provides a float-domain reference for loose numeric checks.
"""

import jax
import jax.numpy as jnp

from . import lnscore as lc


def matmul_ref(am, as_, wm, ws, cfg: lc.LnsConfig, tables):
    """LNS matmul oracle: ``[B,K]·[K,N] → [B,N]`` (m, s) planes."""
    b, k = am.shape
    k2, n = wm.shape
    assert k == k2, "inner-dim mismatch"

    def body(p, carry):
        acc_m, acc_s = carry
        pm, ps = lc.lns_mul(
            am[:, p][:, None], as_[:, p][:, None], wm[p, :][None, :], ws[p, :][None, :], cfg
        )
        return lc.lns_add(acc_m, acc_s, pm, ps, cfg, tables)

    acc_m = jnp.full((b, n), lc.ZERO_M, jnp.int32)
    acc_s = jnp.ones((b, n), jnp.int32)
    return jax.lax.fori_loop(0, k, body, (acc_m, acc_s))


def add_bias_ref(zm, zs, bm, bs, cfg, tables):
    """Row-broadcast ⊞ bias (z as the left operand, matching Rust)."""
    return lc.lns_add(zm, zs, bm[None, :], bs[None, :], cfg, tables)


def col_sum_ref(xm, xs, cfg, tables):
    """Column ⊞-sums, sequential over rows ascending (bias gradient)."""
    rows, n = xm.shape

    def body(i, carry):
        acc_m, acc_s = carry
        return lc.lns_add(acc_m, acc_s, xm[i, :], xs[i, :], cfg, tables)

    acc_m = jnp.full((n,), lc.ZERO_M, jnp.int32)
    acc_s = jnp.ones((n,), jnp.int32)
    return jax.lax.fori_loop(0, rows, body, (acc_m, acc_s))


def matmul_float(a, w):
    """Float-domain reference for loose agreement checks."""
    return a @ w
