"""LNS fixed-point core: configs, Δ tables, and elementwise jnp ops.

This module is the Python mirror of ``rust/src/lns/`` and implements the
**identical integer semantics** (DESIGN.md §5): the Rust native engine and
the HLO artifacts lowered from these functions are bit-exact against each
other, which `rust/tests/cross_check.rs` and `rust/tests/pjrt_roundtrip.rs`
verify.

Representation: a tensor of LNS values is a pair of int32 arrays
``(m, s)`` — ``m`` is the log-magnitude in units of ``2^-q_f`` with
``ZERO_M`` as the exact-zero sentinel, ``s`` is the linear sign with the
paper's convention ``1 ⇔ v > 0``.
"""

from dataclasses import dataclass, field
from typing import Tuple

import numpy as np

import jax.numpy as jnp

# Exact-zero sentinel (Rust: i32::MIN).
ZERO_M = np.int32(-(2**31))
# Δ− singular-bin sentinel: hugely negative, saturates after the clamp.
# (Rust uses i64::MIN/4; any value far below -m_max is equivalent because
# the subsequent add saturates. We stay in int32 range.)
MINUS_SAT = np.int32(-(2**30))


def _to_units(x: np.ndarray, frac_bits: int) -> np.ndarray:
    """Fixed-point quantization, round-half-away-from-zero (Rust to_units)."""
    scaled = np.asarray(x, dtype=np.float64) * float(1 << frac_bits)
    return np.where(scaled >= 0.0, np.floor(scaled + 0.5), np.ceil(scaled - 0.5)).astype(
        np.int64
    )


@dataclass(frozen=True)
class LnsConfig:
    """Word format + Δ approximation (mirror of Rust LnsConfig).

    delta_mode / softmax_delta_mode: "lut" or "bitshift".
    LUT specs are (d_max, log2_inv_r).
    """

    total_bits: int
    frac_bits: int
    delta_mode: str = "lut"
    lut: Tuple[int, int] = (10, 1)  # d_max, log2(1/r)  -> 20 entries
    softmax_delta_mode: str = "lut"
    softmax_lut: Tuple[int, int] = (10, 6)  # -> 640 entries
    name: str = field(default="", compare=False)

    @property
    def m_max(self) -> int:
        return (1 << (self.total_bits - 2)) - 1

    @property
    def m_min(self) -> int:
        return -self.m_max

    def to_units(self, x) -> np.ndarray:
        return _to_units(x, self.frac_bits)


def w16_lut() -> LnsConfig:
    return LnsConfig(16, 10, "lut", (10, 1), "lut", (10, 6), name="w16_lut")


def w12_lut() -> LnsConfig:
    return LnsConfig(12, 6, "lut", (10, 1), "lut", (10, 6), name="w12_lut")


def w16_bitshift() -> LnsConfig:
    return LnsConfig(16, 10, "bitshift", (10, 1), "bitshift", (10, 6), name="w16_bs")


def w12_bitshift() -> LnsConfig:
    return LnsConfig(12, 6, "bitshift", (10, 1), "bitshift", (10, 6), name="w12_bs")


BY_NAME = {
    "w16_lut": w16_lut,
    "w12_lut": w12_lut,
    "w16_bs": w16_bitshift,
    "w12_bs": w12_bitshift,
}


# ---------------------------------------------------------------------
# Tables (mirror of rust delta.rs / linconv.rs — identical rounding)
# ---------------------------------------------------------------------


def delta_tables(cfg: LnsConfig, which: str) -> Tuple[np.ndarray, np.ndarray, int]:
    """Δ± tables in fixed-point units + the index shift.

    ``which`` is "mac" or "softmax". For bit-shift mode returns empty
    tables (the ops compute shifts inline).
    """
    mode = cfg.delta_mode if which == "mac" else cfg.softmax_delta_mode
    if mode != "lut":
        return np.zeros(0, np.int32), np.zeros(0, np.int32), 0
    d_max, log2_inv_r = cfg.lut if which == "mac" else cfg.softmax_lut
    assert log2_inv_r <= cfg.frac_bits, "LUT finer than word resolution"
    n = d_max << log2_inv_r
    r = 1.0 / (1 << log2_inv_r)
    d = np.arange(n, dtype=np.float64) * r
    plus = cfg.to_units(np.log2(1.0 + np.exp2(-d))).astype(np.int32)
    with np.errstate(divide="ignore"):
        minus_f = np.log2(1.0 - np.exp2(-d))
    minus = cfg.to_units(np.where(np.isfinite(minus_f), minus_f, 0.0)).astype(np.int32)
    minus[0] = MINUS_SAT
    shift = cfg.frac_bits - log2_inv_r
    return plus, minus, shift


def pow2_table(cfg: LnsConfig) -> Tuple[np.ndarray, int]:
    """Fractional 2^f table (mirror of rust Pow2Table): entries
    round(2^{i/2^k} · 2^{q_f}) for i in [0, 2^k), k = min(q_f, 10)."""
    k = min(cfg.frac_bits, 10)
    n = 1 << k
    f = np.arange(n, dtype=np.float64) / n
    entries = np.floor(np.exp2(f) * float(1 << cfg.frac_bits) + 0.5).astype(np.int32)
    return entries, k


# ---------------------------------------------------------------------
# Host-side encode/decode (dataset + weight conversion; mirrors Rust)
# ---------------------------------------------------------------------


def encode(v: np.ndarray, cfg: LnsConfig) -> Tuple[np.ndarray, np.ndarray]:
    """Real → (m, s) int32 planes. Zero → (ZERO_M, 1)."""
    v = np.asarray(v, dtype=np.float64)
    nz = v != 0.0
    with np.errstate(divide="ignore"):
        mag = np.log2(np.abs(np.where(nz, v, 1.0)))
    m = np.clip(cfg.to_units(mag), cfg.m_min, cfg.m_max).astype(np.int32)
    m = np.where(nz, m, ZERO_M).astype(np.int32)
    s = np.where(v > 0.0, 1, 0).astype(np.int32)
    s = np.where(nz, s, 1).astype(np.int32)
    return m, s


def decode(m: np.ndarray, s: np.ndarray, cfg: LnsConfig) -> np.ndarray:
    """(m, s) → float64."""
    m = np.asarray(m, dtype=np.int64)
    zero = m == int(ZERO_M)
    mag = np.exp2(np.where(zero, 0, m).astype(np.float64) / float(1 << cfg.frac_bits))
    out = np.where(np.asarray(s) == 1, mag, -mag)
    return np.where(zero, 0.0, out)


# ---------------------------------------------------------------------
# Traced (jnp) elementwise ops — these lower into the artifacts
# ---------------------------------------------------------------------


def _sat(m, cfg: LnsConfig):
    return jnp.clip(m, cfg.m_min, cfg.m_max)


def lns_mul(mx, sx, my, sy, cfg: LnsConfig):
    """⊡: add magnitudes (saturating), XNOR signs; zero annihilates."""
    zx = mx == ZERO_M
    zy = my == ZERO_M
    z = zx | zy
    mm = _sat(jnp.where(zx, 0, mx) + jnp.where(zy, 0, my), cfg)
    m = jnp.where(z, ZERO_M, mm).astype(jnp.int32)
    s = jnp.where(z, 1, 1 - (sx ^ sy)).astype(jnp.int32)
    return m, s


def _delta_plus(d, cfg: LnsConfig, tables):
    """Δ+ of a non-negative difference in units (int32 → int32).

    LUT lookups use round-to-nearest sample indexing (`(d + bin/2) >>
    shift`) — floor indexing systematically overestimates the decreasing
    Δ+, which compounds across long ⊞ reductions and destabilizes
    training (mirrors rust/src/lns/delta.rs).
    """
    plus, _minus, shift = tables
    if plus.shape[0] == 0:  # bit-shift mode
        sh = jnp.minimum(d >> cfg.frac_bits, 31)
        return (jnp.int32(1 << cfg.frac_bits) >> sh).astype(jnp.int32)
    idx = (d + (1 << shift >> 1)) >> shift
    n = plus.shape[0]
    t = jnp.asarray(plus, dtype=jnp.int32)
    return jnp.where(idx >= n, 0, t[jnp.clip(idx, 0, n - 1)]).astype(jnp.int32)


def _delta_minus(d, cfg: LnsConfig, tables):
    """Δ− of a positive difference in units (int32 → int32, ≤ 0)."""
    _plus, minus, shift = tables
    if minus.shape[0] == 0:  # bit-shift mode
        sh = jnp.minimum(d >> cfg.frac_bits, 31)
        base = jnp.int32((3 << cfg.frac_bits) >> 1)
        return (-(base >> sh)).astype(jnp.int32)
    idx = (d + (1 << shift >> 1)) >> shift  # nearest-sample (see _delta_plus)
    n = minus.shape[0]
    t = jnp.asarray(minus, dtype=jnp.int32)
    return jnp.where(idx >= n, 0, t[jnp.clip(idx, 0, n - 1)]).astype(jnp.int32)


def lns_add(mx, sx, my, sy, cfg: LnsConfig, tables):
    """⊞ (Eq. 3): max + Δ±(|X−Y|) with the given Δ tables."""
    zx = mx == ZERO_M
    zy = my == ZERO_M
    # Mask zeros out of the arithmetic then select at the end.
    mxs = jnp.where(zx, 0, mx)
    mys = jnp.where(zy, 0, my)
    x_bigger = mxs > mys
    mmax = jnp.maximum(mxs, mys)
    d = jnp.abs(mxs - mys)
    s_z = jnp.where(x_bigger, sx, sy).astype(jnp.int32)
    same = sx == sy

    m_same = _sat(mmax + _delta_plus(d, cfg, tables), cfg)
    # Opposite signs: d == 0 → exact cancellation (ZERO); else saturated.
    dm = _delta_minus(jnp.maximum(d, 1), cfg, tables)
    m_diff = _sat(mmax + dm, cfg)
    cancel = (~same) & (d == 0)

    m = jnp.where(same, m_same, m_diff).astype(jnp.int32)
    m = jnp.where(cancel, ZERO_M, m)
    s = jnp.where(cancel, 1, s_z)
    # Zero-operand identities.
    m = jnp.where(zx, my, jnp.where(zy, mx, m)).astype(jnp.int32)
    s = jnp.where(zx, sy, jnp.where(zy, sx, s)).astype(jnp.int32)
    return m, s


def lns_sub(mx, sx, my, sy, cfg: LnsConfig, tables):
    """⊟ (Eq. 5): flip the second sign, but keep exact-zero's canonical +."""
    sy_f = jnp.where(my == ZERO_M, sy, 1 - sy).astype(jnp.int32)
    return lns_add(mx, sx, my, sy_f, cfg, tables)


def llrelu(m, s, cfg: LnsConfig, beta_units: int):
    """llReLU (Eq. 11): negative values get β added to the magnitude."""
    neg = (s == 0) & (m != ZERO_M)
    shifted = _sat(m + jnp.int32(beta_units), cfg)
    return jnp.where(neg, shifted, m).astype(jnp.int32), s


def llrelu_bwd(pre_m, pre_s, up_m, up_s, cfg: LnsConfig, beta_units: int):
    """llReLU backprop: scale upstream by the slope where preact < 0."""
    neg = (pre_s == 0) & (pre_m != ZERO_M) & (up_m != ZERO_M)
    shifted = _sat(up_m + jnp.int32(beta_units), cfg)
    return jnp.where(neg, shifted, up_m).astype(jnp.int32), up_s


def softmax_logit_units(m, s, cfg: LnsConfig, p2):
    """m-field of (a·log2 e) (Eq. 14a prep; mirrors Rust
    softmax_logit_units): one shift-and-LUT 2^x evaluation."""
    entries, k = p2
    q = cfg.frac_bits
    c1 = int(cfg.to_units(np.log2(np.log2(np.e))))
    e_units = m + jnp.int32(c1 + (q << q))
    i_part = e_units >> q  # arithmetic shift = floor division
    f_part = e_units - (i_part << q)
    t = jnp.asarray(entries, dtype=jnp.int32)
    entry = t[f_part >> (q - k)]
    shift = i_part - q
    # Positive shifts: entry << shift (values stay well inside int32 for
    # the clamped exponent range); negative: round-half-up right shift.
    # Clip the left shift so entry<<shift stays inside int32: entry < 2^11
    # and any true shift > 18 yields ≥ 2^28 ≫ m_max, so the min() below
    # saturates identically.
    pos_shift = jnp.clip(shift, 0, 18)
    neg_shift = jnp.clip(-shift, 1, 31)
    up = entry << pos_shift
    down = (entry + (jnp.int32(1) << (neg_shift - 1))) >> neg_shift
    mag = jnp.where(shift >= 0, up, down)
    mag = jnp.minimum(mag, cfg.m_max)
    t_units = jnp.where(s == 1, mag, -mag)
    return jnp.where(m == ZERO_M, 0, t_units).astype(jnp.int32)


def log_softmax_ce_grad(logits_m, logits_s, labels, cfg: LnsConfig, sm_tables, p2):
    """Eq. 14: returns (δ_m, δ_s, log2p_label_units).

    ``logits_*``: [batch, C]; ``labels``: int32 [batch].
    Reduction over classes is sequential ascending (bit-exact with Rust).
    """
    batch, classes = logits_m.shape
    t = softmax_logit_units(logits_m, logits_s, cfg, p2)  # [B, C] int32

    # lse = ⊞_j (t_j, +): sequential over classes.
    lse_m = jnp.full((batch,), ZERO_M, jnp.int32)
    lse_s = jnp.ones((batch,), jnp.int32)
    for j in range(classes):
        lse_m, lse_s = lns_add(lse_m, lse_s, t[:, j], jnp.ones((batch,), jnp.int32), cfg, sm_tables)
    lse_val = jnp.where(lse_m == ZERO_M, cfg.m_min, lse_m)

    # log2 p_j = t_j − lse (plain saturating fixed-point subtract).
    p_m = jnp.clip(t - lse_val[:, None], cfg.m_min, cfg.m_max).astype(jnp.int32)
    p_s = jnp.ones_like(p_m)

    onehot = (jnp.arange(classes)[None, :] == labels[:, None])
    # δ = p ⊟ y: y = 1 (m=0,s=1) at the label, exact zero elsewhere.
    y_m = jnp.where(onehot, 0, ZERO_M).astype(jnp.int32)
    y_s = jnp.ones_like(y_m)
    d_m, d_s = lns_sub(p_m, p_s, y_m, y_s, cfg, sm_tables)

    log2p_label = jnp.sum(jnp.where(onehot, p_m, 0), axis=1).astype(jnp.int32)
    return d_m, d_s, log2p_label
