"""L1 Pallas kernel: LNS matrix multiply.

The paper's MAC — `⊞_k (A[i,k] ⊡ W[k,j])` — as a tiled Pallas kernel.

TPU adaptation (DESIGN.md §7): LNS tensors are int32 (magnitude, sign)
planes; the ⊞ reduction is vectorized `max`/`sub`/`gather`/`add` — VPU
work, with the Δ LUT (≤640×4 B) resident in VMEM and the operand tiles
streamed HBM→VMEM exactly like a dense matmul. The MXU cannot express
table lookups, so the kernel deliberately targets the vector unit; the
`BlockSpec` grid below is the HBM↔VMEM schedule.

`interpret=True` always: the CPU PJRT plugin cannot run Mosaic
custom-calls, and interpret-mode lowers to plain HLO that both pytest and
the Rust runtime execute. Numerics are identical either way; real-TPU
performance is *estimated* (EXPERIMENTS.md §Perf) from the VMEM footprint
and arithmetic intensity.
"""

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import lnscore as lc


def _mac_kernel(*refs, cfg, index_shift, use_lut, k):
    """One (bm × bn) output tile: sequential ⊞ over the full K axis.

    Ref order: `am, as, wm, ws[, table_plus, table_minus], om, os` — the Δ
    tables ride along as (tiny, VMEM-resident) inputs when in LUT mode.
    """
    if use_lut:
        am_ref, as_ref, wm_ref, ws_ref, tp_ref, tm_ref, om_ref, os_ref = refs
        tables = (tp_ref[...], tm_ref[...], index_shift)
    else:
        am_ref, as_ref, wm_ref, ws_ref, om_ref, os_ref = refs
        import numpy as _np

        tables = (_np.zeros(0, _np.int32), _np.zeros(0, _np.int32), 0)
    am = am_ref[...]
    as_ = as_ref[...]
    wm = wm_ref[...]
    ws = ws_ref[...]
    bm, bn = om_ref.shape

    def body(p, carry):
        acc_m, acc_s = carry
        pm, ps = lc.lns_mul(
            jax.lax.dynamic_slice_in_dim(am, p, 1, 1),
            jax.lax.dynamic_slice_in_dim(as_, p, 1, 1),
            jax.lax.dynamic_slice_in_dim(wm, p, 1, 0),
            jax.lax.dynamic_slice_in_dim(ws, p, 1, 0),
            cfg,
        )
        return lc.lns_add(acc_m, acc_s, pm, ps, cfg, tables)

    acc_m = jnp.full((bm, bn), lc.ZERO_M, jnp.int32)
    acc_s = jnp.ones((bm, bn), jnp.int32)
    acc_m, acc_s = jax.lax.fori_loop(0, k, body, (acc_m, acc_s))
    om_ref[...] = acc_m
    os_ref[...] = acc_s


def lns_matmul(am, as_, wm, ws, cfg: lc.LnsConfig, tables, block_m: int = 8, block_n: int = 128):
    """Tiled LNS matmul `[B,K]·[K,N] → [B,N]` via `pallas_call`.

    The grid tiles the *output*; each program instance streams its
    `(block_m, K)` and `(K, block_n)` operand tiles and reduces over K in
    VMEM. Δ tables are closed over as kernel constants (they are what a
    TPU build would pin in VMEM).
    """
    b, k = am.shape
    k2, n = wm.shape
    assert k == k2, "inner-dim mismatch"
    bm = min(block_m, b)
    bn = min(block_n, n)
    # Shrink blocks to divide the problem exactly (shapes here are the
    # paper's fixed MLP dims; generality beyond divisibility isn't needed).
    while b % bm:
        bm -= 1
    while n % bn:
        bn -= 1

    table_plus, table_minus, index_shift = tables
    use_lut = int(np.asarray(table_plus).shape[0]) > 0
    kern = functools.partial(
        _mac_kernel, cfg=cfg, index_shift=index_shift, use_lut=use_lut, k=k
    )
    grid = (b // bm, n // bn)
    out_shape = [
        jax.ShapeDtypeStruct((b, n), jnp.int32),
        jax.ShapeDtypeStruct((b, n), jnp.int32),
    ]
    in_specs = [
        pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        pl.BlockSpec((k, bn), lambda i, j: (0, j)),
    ]
    operands = [am, as_, wm, ws]
    if use_lut:
        nt = int(np.asarray(table_plus).shape[0])
        in_specs += [
            pl.BlockSpec((nt,), lambda i, j: (0,)),
            pl.BlockSpec((nt,), lambda i, j: (0,)),
        ]
        operands += [jnp.asarray(table_plus, jnp.int32), jnp.asarray(table_minus, jnp.int32)]
    out_specs = [
        pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
    ]
    om, os_ = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=True,
    )(*operands)
    return om, os_
