"""L2: the paper's MLP in JAX, forward + manual log-domain backward.

Autodiff cannot differentiate the discrete LNS ops, so — exactly as the
paper does — the backward pass is written out in ⊞/⊡ (mirroring
``rust/src/nn/mlp.rs`` operation-for-operation, including reduction
orders, so the lowered artifacts are bit-exact against the native
engine).

Parameters travel as explicit arrays (m, s planes per tensor); the
train-step artifact returns the updated parameters, making the Rust
coordinator the owner of all state.
"""

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import lnscore as lc
from .kernels.lns_matmul import lns_matmul
from .kernels import ref


class LnsModelSpec(NamedTuple):
    """Static config for one lowered model variant."""

    cfg: lc.LnsConfig
    dims: Sequence[int]  # e.g. (784, 100, 10)
    batch: int
    lr: float = 0.01
    weight_decay: float = 1e-4
    slope: float = 0.01  # leaky/llReLU slope; β = log2(slope)
    use_pallas: bool = True  # pallas kernel vs pure-jnp oracle for matmul


def _tables(spec: LnsModelSpec):
    mac = lc.delta_tables(spec.cfg, "mac")
    sm = lc.delta_tables(spec.cfg, "softmax")
    p2 = lc.pow2_table(spec.cfg)
    return mac, sm, p2


def _beta_units(spec: LnsModelSpec) -> int:
    return int(spec.cfg.to_units(np.log2(spec.slope)))


def _matmul(spec: LnsModelSpec, tables, am, as_, wm, ws):
    if spec.use_pallas:
        return lns_matmul(am, as_, wm, ws, spec.cfg, tables)
    return ref.matmul_ref(am, as_, wm, ws, spec.cfg, tables)


def param_names(dims: Sequence[int]):
    """Flat parameter order: per layer W then b, each as (m, s)."""
    names = []
    for l in range(len(dims) - 1):
        names += [f"w{l}m", f"w{l}s", f"b{l}m", f"b{l}s"]
    return names


def init_params(spec: LnsModelSpec, seed: int = 0):
    """He-normal float init → encode (the paper's Eq.-12-equivalent
    route); returns the flat list matching :func:`param_names`."""
    rng = np.random.default_rng(seed)
    out = []
    for l in range(len(spec.dims) - 1):
        fan_in, fan_out = spec.dims[l], spec.dims[l + 1]
        w = rng.normal(0.0, np.sqrt(2.0 / fan_in), size=(fan_in, fan_out))
        wm, ws = lc.encode(w, spec.cfg)
        bm, bs = lc.encode(np.zeros(fan_out), spec.cfg)
        out += [jnp.asarray(wm), jnp.asarray(ws), jnp.asarray(bm), jnp.asarray(bs)]
    return out


def lns_forward(spec: LnsModelSpec, params, xm, xs):
    """Forward pass → logits (m, s). Hidden layers use llReLU (Eq. 11)."""
    mac, sm, p2 = _tables(spec)
    del sm, p2
    beta = _beta_units(spec)
    n_layers = len(spec.dims) - 1
    am, as_ = xm, xs
    zs = []
    acts = [(am, as_)]
    for l in range(n_layers):
        wm, ws, bm, bs = params[4 * l : 4 * l + 4]
        zm, zsg = _matmul(spec, mac, am, as_, wm, ws)
        zm, zsg = ref.add_bias_ref(zm, zsg, bm, bs, spec.cfg, mac)
        zs.append((zm, zsg))
        if l + 1 < n_layers:
            am, as_ = lc.llrelu(zm, zsg, spec.cfg, beta)
        else:
            am, as_ = zm, zsg
        acts.append((am, as_))
    return zs, acts


def lns_logits(spec: LnsModelSpec, params, xm, xs):
    """Inference entry point: logits only."""
    _, acts = lns_forward(spec, params, xm, xs)
    return acts[-1]


def lns_train_step(spec: LnsModelSpec, params, xm, xs, labels):
    """One SGD step, entirely in LNS (mirrors Rust `Mlp::backprop` +
    `SgdConfig::apply`). Returns (new_params, log2p_label_units)."""
    cfg = spec.cfg
    mac, sm, p2 = _tables(spec)
    beta = _beta_units(spec)
    n_layers = len(spec.dims) - 1
    batch = spec.batch

    zs, acts = lns_forward(spec, params, xm, xs)
    logits_m, logits_s = acts[-1]

    # Soft-max + CE gradient init (Eq. 14) with the finer Δ tables.
    d_m, d_s, log2p = lc.log_softmax_ce_grad(logits_m, logits_s, labels, cfg, sm, p2)

    inv_b_m, inv_b_s = (int(v) for v in lc.encode(1.0 / batch, cfg))
    lr_m, lr_s = (int(v) for v in lc.encode(spec.lr, cfg))
    wd_m, wd_s = (int(v) for v in lc.encode(spec.weight_decay, cfg))
    use_wd = spec.weight_decay != 0.0

    def scale(m, s, cm, cs):
        return lc.lns_mul(m, s, jnp.int32(cm), jnp.int32(cs), cfg)

    new_params = list(params)
    for l in range(n_layers - 1, -1, -1):
        wm, ws, bm, bs = params[4 * l : 4 * l + 4]
        a_m, a_s = acts[l]
        # dW = aᵀ · δ (ascending-batch reduction), scaled by 1/B.
        gm, gs = _matmul(spec, mac, a_m.T, a_s.T, d_m, d_s)
        gm, gs = scale(gm, gs, inv_b_m, inv_b_s)
        # db = column ⊞-sum of δ, scaled by 1/B.
        dbm, dbs = ref.col_sum_ref(d_m, d_s, cfg, mac)
        dbm, dbs = scale(dbm, dbs, inv_b_m, inv_b_s)
        # Backprop to the previous layer (before updating W!).
        if l > 0:
            back_m, back_s = _matmul(spec, mac, d_m, d_s, wm.T, ws.T)
            pz_m, pz_s = zs[l - 1]
            d_m, d_s = lc.llrelu_bwd(pz_m, pz_s, back_m, back_s, cfg, beta)
        # SGD update: g' = g ⊞ λ⊡w ;  w ← w ⊟ η⊡g'   (weights only get wd).
        if use_wd:
            wdm, wds = scale(wm, ws, wd_m, wd_s)
            gm, gs = lc.lns_add(gm, gs, wdm, wds, cfg, mac)
        sm_, ss_ = scale(gm, gs, lr_m, lr_s)
        nwm, nws = lc.lns_sub(wm, ws, sm_, ss_, cfg, mac)
        sb_m, sb_s = scale(dbm, dbs, lr_m, lr_s)
        nbm, nbs = lc.lns_sub(bm, bs, sb_m, sb_s, cfg, mac)
        new_params[4 * l : 4 * l + 4] = [nwm, nws, nbm, nbs]

    return new_params, log2p


# ---------------------------------------------------------------------
# Float baseline (lowered for the PJRT float artifacts)
# ---------------------------------------------------------------------


def float_init(dims: Sequence[int], seed: int = 0):
    """He-normal float parameters (W, b per layer)."""
    rng = np.random.default_rng(seed)
    out = []
    for l in range(len(dims) - 1):
        fan_in, fan_out = dims[l], dims[l + 1]
        out.append(jnp.asarray(rng.normal(0.0, np.sqrt(2.0 / fan_in), (fan_in, fan_out)), jnp.float32))
        out.append(jnp.zeros((fan_out,), jnp.float32))
    return out


def float_logits(params, x, slope=0.01):
    """Float forward (leaky-ReLU hidden, linear head)."""
    n_layers = len(params) // 2
    a = x
    for l in range(n_layers):
        w, b = params[2 * l], params[2 * l + 1]
        z = a @ w + b
        a = jnp.where(z > 0, z, slope * z) if l + 1 < n_layers else z
    return a


def float_train_step(params, x, labels, lr=0.01, weight_decay=1e-4, slope=0.01):
    """One float SGD step via jax.grad (the conventional baseline)."""

    def loss_fn(ps):
        logits = float_logits(ps, x, slope)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
        return nll

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new = []
    for l in range(len(params) // 2):
        w, b = params[2 * l], params[2 * l + 1]
        gw, gb = grads[2 * l], grads[2 * l + 1]
        new.append(w - lr * (gw + weight_decay * w))
        new.append(b - lr * gb)
    return new, loss


# ---------------------------------------------------------------------
# Jittable entry points (what aot.py lowers)
# ---------------------------------------------------------------------


def make_lns_fwd_fn(spec: LnsModelSpec):
    """`(params..., xm, xs) -> (logits_m, logits_s)`, jit-ready."""
    n = 4 * (len(spec.dims) - 1)

    def fn(*args):
        params = list(args[:n])
        xm, xs = args[n], args[n + 1]
        m, s = lns_logits(spec, params, xm, xs)
        return (m, s)

    return fn


def make_lns_train_fn(spec: LnsModelSpec):
    """`(params..., xm, xs, labels) -> (new_params..., log2p)`, jit-ready."""
    n = 4 * (len(spec.dims) - 1)

    def fn(*args):
        params = list(args[:n])
        xm, xs, labels = args[n], args[n + 1], args[n + 2]
        new_params, log2p = lns_train_step(spec, params, xm, xs, labels)
        return tuple(new_params) + (log2p,)

    return fn


def make_float_fwd_fn(dims, slope=0.01):
    """Float logits entry point."""
    n = 2 * (len(dims) - 1)

    def fn(*args):
        params = list(args[:n])
        x = args[n]
        return (float_logits(params, x, slope),)

    return fn


def make_float_train_fn(dims, lr=0.01, weight_decay=1e-4, slope=0.01):
    """Float train-step entry point."""
    n = 2 * (len(dims) - 1)

    def fn(*args):
        params = list(args[:n])
        x, labels = args[n], args[n + 1]
        new, loss = float_train_step(params, x, labels, lr, weight_decay, slope)
        return tuple(new) + (loss,)

    return fn
