"""AOT compiler: lower every model variant to HLO text + metadata.

Run once via ``make artifacts``; Python never runs at request time.

Outputs under ``--out-dir`` (default ``../artifacts``):
* ``<name>.hlo.txt``  — HLO text per artifact (the interchange format the
  image's xla_extension 0.5.1 accepts; serialized protos from jax ≥ 0.5
  are rejected — see /opt/xla-example/README.md),
* ``manifest.tsv``    — name/file/kind/bits/delta/dims/batch registry rows,
* ``golden_lns.tsv``  — cross-language golden vectors: random op
  inputs/outputs per config, compared bit-exactly by
  ``rust/tests/cross_check.rs``,
* ``golden_tables.tsv`` — the Δ±/pow2 tables themselves.
"""

import argparse
import os
import sys

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import lnscore as lc


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange).

    ``print_large_constants=True`` is essential: the default printer elides
    big constants as ``constant({...})`` — which XLA 0.5.1's text parser
    accepts *silently*, replacing the Δ/pow2 tables with garbage. (Found
    the hard way; guarded by `rust/tests/pjrt_roundtrip.rs`.)
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)


# The lowered variants. Small dims keep test-time compiles snappy; the
# paper dims are the real deployment artifacts.
PAPER_DIMS = (784, 100, 10)
SMALL_DIMS = (12, 8, 4)

LNS_CONFIGS = ["w16_lut", "w12_lut", "w16_bs", "w12_bs"]


def lns_specs():
    specs = []
    for name in LNS_CONFIGS:
        cfg = lc.BY_NAME[name]()
        specs.append(M.LnsModelSpec(cfg=cfg, dims=PAPER_DIMS, batch=5))
    # Small variants (16-bit LUT only) for fast integration tests.
    specs.append(M.LnsModelSpec(cfg=lc.w16_lut(), dims=SMALL_DIMS, batch=3))
    return specs


def spec_tag(spec: M.LnsModelSpec) -> str:
    size = "small" if tuple(spec.dims) == SMALL_DIMS else "paper"
    return f"{spec.cfg.name}_{size}"


def lower_lns(spec: M.LnsModelSpec, out_dir: str, manifest: list):
    cfg = spec.cfg
    delta_tag = "lut" if cfg.delta_mode == "lut" else "bs"
    dims_s = "x".join(str(d) for d in spec.dims)
    i32 = jnp.int32

    def shape(d):
        return jax.ShapeDtypeStruct(d, i32)

    param_shapes = []
    for l in range(len(spec.dims) - 1):
        fi, fo = spec.dims[l], spec.dims[l + 1]
        param_shapes += [shape((fi, fo)), shape((fi, fo)), shape((fo,)), shape((fo,))]

    # Forward (inference) artifact: batch 64 for paper dims, batch for small.
    fwd_batch = 64 if tuple(spec.dims) == PAPER_DIMS else spec.batch
    fwd_fn = M.make_lns_fwd_fn(spec)
    fwd_args = param_shapes + [shape((fwd_batch, spec.dims[0]))] * 2
    name = f"lns_fwd_{spec_tag(spec)}"
    text = to_hlo_text(jax.jit(fwd_fn).lower(*fwd_args))
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    manifest.append((name, f"{name}.hlo.txt", "fwd", cfg.total_bits, delta_tag, dims_s, fwd_batch))
    print(f"  {name}: {len(text)} chars")

    # Train-step artifact (paper batch).
    train_fn = M.make_lns_train_fn(spec)
    train_args = param_shapes + [
        shape((spec.batch, spec.dims[0])),
        shape((spec.batch, spec.dims[0])),
        shape((spec.batch,)),
    ]
    name = f"lns_train_{spec_tag(spec)}"
    text = to_hlo_text(jax.jit(train_fn).lower(*train_args))
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    manifest.append(
        (name, f"{name}.hlo.txt", "train_step", cfg.total_bits, delta_tag, dims_s, spec.batch)
    )
    print(f"  {name}: {len(text)} chars")


def lower_float(dims, out_dir: str, manifest: list):
    dims_s = "x".join(str(d) for d in dims)
    f32 = jnp.float32

    def shape(d):
        return jax.ShapeDtypeStruct(d, f32)

    param_shapes = []
    for l in range(len(dims) - 1):
        param_shapes += [shape((dims[l], dims[l + 1])), shape((dims[l + 1],))]

    fwd = M.make_float_fwd_fn(dims)
    name = "float_fwd_paper"
    text = to_hlo_text(jax.jit(fwd).lower(*(param_shapes + [shape((64, dims[0]))])))
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    manifest.append((name, f"{name}.hlo.txt", "float_fwd", 0, "-", dims_s, 64))
    print(f"  {name}: {len(text)} chars")

    train = M.make_float_train_fn(dims)
    name = "float_train_paper"
    args = param_shapes + [
        shape((5, dims[0])),
        jax.ShapeDtypeStruct((5,), jnp.int32),
    ]
    text = to_hlo_text(jax.jit(train).lower(*args))
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    manifest.append((name, f"{name}.hlo.txt", "float_train", 0, "-", dims_s, 5))
    print(f"  {name}: {len(text)} chars")


# ---------------------------------------------------------------------
# Golden vectors (cross-language bit-exactness corpus)
# ---------------------------------------------------------------------


def random_lns(rng, cfg, n, zero_frac=0.1):
    m = rng.integers(cfg.m_min, cfg.m_max + 1, size=n).astype(np.int32)
    z = rng.random(n) < zero_frac
    m = np.where(z, lc.ZERO_M, m).astype(np.int32)
    s = rng.integers(0, 2, size=n).astype(np.int32)
    s = np.where(z, 1, s).astype(np.int32)
    return m, s


def write_golden(out_dir: str, n_cases: int = 200):
    rows = ["# config\top\tinputs...\toutputs..."]
    trows = ["# config\ttable\tindex\tvalue"]
    for cname in LNS_CONFIGS:
        cfg = lc.BY_NAME[cname]()
        mac = lc.delta_tables(cfg, "mac")
        sm = lc.delta_tables(cfg, "softmax")
        p2 = lc.pow2_table(cfg)
        beta = int(cfg.to_units(np.log2(0.01)))
        rng = np.random.default_rng(hash(cname) % (2**31))

        # Tables.
        for tname, arr in [
            ("delta_plus", mac[0]),
            ("delta_minus", mac[1]),
            ("sm_delta_plus", sm[0]),
            ("sm_delta_minus", sm[1]),
            ("pow2", p2[0]),
        ]:
            for i, v in enumerate(np.asarray(arr)):
                trows.append(f"{cname}\t{tname}\t{i}\t{int(v)}")

        # Scalar ops.
        mx, sx = random_lns(rng, cfg, n_cases)
        my, sy = random_lns(rng, cfg, n_cases)
        for op in ["mul", "add", "sub"]:
            fn = {"mul": lambda: lc.lns_mul(mx, sx, my, sy, cfg),
                  "add": lambda: lc.lns_add(mx, sx, my, sy, cfg, mac),
                  "sub": lambda: lc.lns_sub(mx, sx, my, sy, cfg, mac)}[op]
            om, os_ = (np.asarray(v) for v in fn())
            for i in range(n_cases):
                rows.append(
                    f"{cname}\t{op}\t{mx[i]}\t{sx[i]}\t{my[i]}\t{sy[i]}\t{om[i]}\t{os_[i]}"
                )

        # llReLU fwd.
        om, os_ = (np.asarray(v) for v in lc.llrelu(jnp.asarray(mx), jnp.asarray(sx), cfg, beta))
        for i in range(n_cases):
            rows.append(f"{cname}\tllrelu\t{mx[i]}\t{sx[i]}\t{om[i]}\t{os_[i]}")

        # Soft-max logit conversion.
        t = np.asarray(lc.softmax_logit_units(jnp.asarray(mx), jnp.asarray(sx), cfg, p2))
        for i in range(n_cases):
            rows.append(f"{cname}\tsoftmax_logit\t{mx[i]}\t{sx[i]}\t{t[i]}")

        # Full soft-max + CE grad rows (batch 4 × 5 classes).
        lm = np.stack([random_lns(rng, cfg, 5, 0.05)[0] for _ in range(4)])
        ls = np.stack([random_lns(rng, cfg, 5, 0.05)[1] for _ in range(4)])
        labels = rng.integers(0, 5, size=4).astype(np.int32)
        dm, dsn, lp = (np.asarray(v) for v in lc.log_softmax_ce_grad(
            jnp.asarray(lm), jnp.asarray(ls), jnp.asarray(labels), cfg, sm, p2))
        for b in range(4):
            ins = "\t".join(f"{lm[b, j]}\t{ls[b, j]}" for j in range(5))
            outs = "\t".join(f"{dm[b, j]}\t{dsn[b, j]}" for j in range(5))
            rows.append(f"{cname}\tsoftmax_grad\t{labels[b]}\t{ins}\t{outs}\t{lp[b]}")

    with open(os.path.join(out_dir, "golden_lns.tsv"), "w") as f:
        f.write("\n".join(rows) + "\n")
    with open(os.path.join(out_dir, "golden_tables.tsv"), "w") as f:
        f.write("\n".join(trows) + "\n")
    print(f"  golden vectors: {len(rows)} rows; tables: {len(trows)} rows")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-paper", action="store_true", help="small artifacts only (fast tests)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = []
    print("lowering LNS variants…")
    for spec in lns_specs():
        if args.skip_paper and tuple(spec.dims) == PAPER_DIMS:
            continue
        lower_lns(spec, args.out_dir, manifest)
    if not args.skip_paper:
        print("lowering float baseline…")
        lower_float(PAPER_DIMS, args.out_dir, manifest)

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        for row in manifest:
            f.write("\t".join(str(x) for x in row) + "\n")
    print(f"manifest: {len(manifest)} artifacts")

    write_golden(args.out_dir)
    print("AOT bundle complete:", os.path.abspath(args.out_dir))
    return 0


if __name__ == "__main__":
    sys.exit(main())
